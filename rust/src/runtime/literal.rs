//! HostTensor <-> xla::Literal conversion.

use anyhow::{bail, Result};

use crate::tensor::HostTensor;

/// f32 HostTensor -> Literal.
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let bytes: &[u8] = bytemuck_cast_f32(t.data());
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        t.shape(),
        bytes,
    )?)
}

/// i32 labels -> Literal (rank-1).
pub fn labels_literal(labels: &[i32]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(labels.as_ptr() as *const u8, labels.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[labels.len()],
        bytes,
    )?)
}

/// Literal -> f32 HostTensor (element type must be F32).
pub fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l.shape()?;
    let dims: Vec<usize> = match &shape {
        xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
        other => bail!("expected array literal, got {other:?}"),
    };
    let data = l.to_vec::<f32>()?;
    HostTensor::new(dims, data)
}

fn bytemuck_cast_f32(data: &[f32]) -> &[u8] {
    // f32 -> u8 reinterpretation is always valid (no alignment increase).
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let l = to_literal(&t).unwrap();
        let t2 = from_literal(&l).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn labels_shape() {
        let l = labels_literal(&[1, 2, 3]).unwrap();
        let shape = l.shape().unwrap();
        match shape {
            xla::Shape::Array(a) => {
                assert_eq!(a.dims(), &[3]);
                assert_eq!(a.ty(), xla::ElementType::S32);
            }
            _ => panic!("not an array"),
        }
    }
}
