//! HostTensor <-> xla::Literal conversion.

use anyhow::{bail, Result};

use crate::tensor::HostTensor;

/// f32 HostTensor -> Literal (one copy of the data, no byte encoding).
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    Ok(xla::Literal::from_f32(t.shape(), t.data().to_vec())?)
}

/// i32 labels -> Literal (rank-1).
pub fn labels_literal(labels: &[i32]) -> Result<xla::Literal> {
    Ok(xla::Literal::from_i32(&[labels.len()], labels.to_vec())?)
}

/// Literal -> f32 HostTensor (element type must be F32).
pub fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l.shape()?;
    let dims: Vec<usize> = match &shape {
        xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
        other => bail!("expected array literal, got {other:?}"),
    };
    let data = l.as_f32()?.to_vec();
    HostTensor::new(dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let l = to_literal(&t).unwrap();
        let t2 = from_literal(&l).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn labels_shape() {
        let l = labels_literal(&[1, 2, 3]).unwrap();
        let shape = l.shape().unwrap();
        match shape {
            xla::Shape::Array(a) => {
                assert_eq!(a.dims(), &[3]);
                assert_eq!(a.ty(), xla::ElementType::S32);
            }
            _ => panic!("not an array"),
        }
    }
}
