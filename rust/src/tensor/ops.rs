//! Allocation-free vector math for the parameter-server hot loop.
//!
//! The momentum-SGD update (paper eq. (3)–(4)) is a handful of axpy-style
//! passes over flat f32 slices; keeping them branchless and in-place keeps
//! the L3 coordinator off the profile (DESIGN.md §Perf L3 target).

/// y += alpha * x (slices must be the same length).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// x *= alpha.
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// out = a - b, in place into `out`.
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, ai), bi) in out.iter_mut().zip(a).zip(b) {
        *o = ai - bi;
    }
}

/// Fused momentum-SGD update of paper eq. (3)–(4), in place:
/// `v <- mu v - eta (g + lambda w); w <- w + v`.
///
/// Written as one zipped pass so the compiler can elide bounds checks
/// and autovectorize: this is the publish hot loop of the sharded
/// parameter server and must run at memory bandwidth (DESIGN.md §Perf
/// L3 target). The arithmetic order matches the historical per-index
/// loop exactly, so trajectories are bit-identical.
pub fn momentum_sgd_step(w: &mut [f32], v: &mut [f32], g: &[f32], mu: f32, eta: f32, lambda: f32) {
    debug_assert_eq!(w.len(), v.len());
    debug_assert_eq!(w.len(), g.len());
    for ((wi, vi), gi) in w.iter_mut().zip(v.iter_mut()).zip(g) {
        let nv = mu * *vi - eta * (*gi + lambda * *wi);
        *vi = nv;
        *wi += nv;
    }
}

/// [`momentum_sgd_step`] with the gradient scaled by `s` in place:
/// `v <- mu v - eta (s g + lambda w); w <- w + v`. Used by the
/// FLOPS-proportional batch plan's weighted publishes; `s = 1.0`
/// multiplies exactly and is bit-identical to the unscaled step.
pub fn momentum_sgd_step_scaled(
    w: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    s: f32,
    mu: f32,
    eta: f32,
    lambda: f32,
) {
    debug_assert_eq!(w.len(), v.len());
    debug_assert_eq!(w.len(), g.len());
    for ((wi, vi), gi) in w.iter_mut().zip(v.iter_mut()).zip(g) {
        let nv = mu * *vi - eta * (s * *gi + lambda * *wi);
        *vi = nv;
        *wi += nv;
    }
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

/// L2 norm.
pub fn l2_norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0]);
    }

    #[test]
    fn scale_basic() {
        let mut x = [2.0, -4.0];
        scale(0.25, &mut x);
        assert_eq!(x, [0.5, -1.0]);
    }

    #[test]
    fn sub_into_basic() {
        let mut out = [0.0; 2];
        sub_into(&[3.0, 5.0], &[1.0, 1.0], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn momentum_sgd_step_matches_eq34() {
        // Same numbers as the param-server unit test: mu=0.5, eta=0.1.
        let mut w = [1.0, 2.0];
        let mut v = [0.0, 0.0];
        let g = [1.0, -1.0];
        momentum_sgd_step(&mut w, &mut v, &g, 0.5, 0.1, 0.0);
        assert!((v[0] + 0.1).abs() < 1e-6 && (v[1] - 0.1).abs() < 1e-6);
        assert!((w[0] - 0.9).abs() < 1e-6 && (w[1] - 2.1).abs() < 1e-6);
        momentum_sgd_step(&mut w, &mut v, &g, 0.5, 0.1, 0.0);
        assert!((w[0] - 0.75).abs() < 1e-6);
        assert!((w[1] - 2.25).abs() < 1e-6);
    }

    #[test]
    fn momentum_sgd_step_weight_decay() {
        let mut w = [1.0, 2.0];
        let mut v = [0.0, 0.0];
        momentum_sgd_step(&mut w, &mut v, &[0.0, 0.0], 0.0, 0.1, 0.1);
        assert!((w[0] - 0.99).abs() < 1e-6);
        assert!((w[1] - 1.98).abs() < 1e-6);
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
