//! Host-side f32 tensors: the coordinator's in-memory model/gradient
//! representation. Deliberately minimal — all heavy math happens inside
//! the AOT-compiled XLA artifacts; the host only needs shape bookkeeping,
//! axpy-style SGD updates, and (de)serialization.

mod host;
mod ops;

pub use host::HostTensor;
pub use ops::{
    axpy, dot, l2_norm, momentum_sgd_step, momentum_sgd_step_scaled, scale, sub_into,
};

/// View an f32 slice as raw bytes (host byte order — both the checkpoint
/// writer and the PJRT literal constructors consume host-endian data).
///
/// The single sanctioned f32 reinterpretation site: checkpointing and
/// literal conversion route through here instead of scattering their own
/// `unsafe` blocks (omnilint's unsafe-safety-comment lint keeps it so).
pub fn f32_bytes(data: &[f32]) -> &[u8] {
    // SAFETY: every f32 bit pattern is a valid sequence of u8s, u8's
    // alignment (1) is never stricter than f32's, and the length covers
    // exactly the source slice: size_of_val(data) = 4 * data.len().
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data)) }
}

/// View an i32 slice as raw bytes (host byte order); see [`f32_bytes`].
pub fn i32_bytes(data: &[i32]) -> &[u8] {
    // SAFETY: as in `f32_bytes` — plain-old-data source, alignment only
    // ever relaxes (4 -> 1), length covers exactly the source slice.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data)) }
}

#[cfg(test)]
mod byte_tests {
    #[test]
    fn f32_bytes_match_le_encoding() {
        let data = [1.0f32, -2.0, 0.5];
        let bytes = super::f32_bytes(&data);
        let expect: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        if cfg!(target_endian = "little") {
            assert_eq!(bytes, &expect[..]);
        }
        assert_eq!(bytes.len(), 12);
        assert!(super::f32_bytes(&[]).is_empty());
    }

    #[test]
    fn i32_bytes_match_le_encoding() {
        let data = [7i32, -1, 1 << 20];
        let bytes = super::i32_bytes(&data);
        let expect: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        if cfg!(target_endian = "little") {
            assert_eq!(bytes, &expect[..]);
        }
        assert_eq!(bytes.len(), 12);
    }
}
