//! Host-side f32 tensors: the coordinator's in-memory model/gradient
//! representation. Deliberately minimal — all heavy math happens inside
//! the AOT-compiled XLA artifacts; the host only needs shape bookkeeping,
//! axpy-style SGD updates, and (de)serialization.

mod host;
mod ops;

pub use host::HostTensor;
pub use ops::{
    axpy, dot, l2_norm, momentum_sgd_step, momentum_sgd_step_scaled, scale, sub_into,
};
