//! A dense row-major f32 tensor living in host memory.
//!
//! Storage is `Arc`-backed copy-on-write (DESIGN.md §Perf): `clone()` is
//! an O(1) refcount bump, so a `ParamServer::read()` snapshot of a whole
//! model costs a handful of pointer bumps instead of an O(scalars) deep
//! copy under the server lock. The first `data_mut()` after a snapshot
//! was taken copies the buffer (`Arc::make_mut`), so writers can never
//! disturb a live snapshot; unshared tensors mutate in place with no
//! copy at all.

use std::sync::Arc;

use anyhow::{bail, Result};

/// Dense row-major f32 tensor with copy-on-write storage.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    shape: Vec<usize>,
    data: Arc<Vec<f32>>,
}

impl HostTensor {
    /// Build from shape + data; checks the element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Self { shape, data: Arc::new(data) })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: Arc::new(vec![0.0; n]) }
    }

    /// Gaussian(0, std) init (paper Appendix F-B uses std 0.01).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::rng::Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal_ms(0.0, std as f64) as f32).collect();
        Self { shape: shape.to_vec(), data: Arc::new(data) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        self.data.as_slice()
    }

    /// Mutable view; copy-on-write if the buffer is shared with a
    /// snapshot (cheap no-op when this tensor is the sole owner).
    pub fn data_mut(&mut self) -> &mut [f32] {
        Arc::make_mut(&mut self.data).as_mut_slice()
    }

    pub fn into_data(self) -> Vec<f32> {
        Arc::try_unwrap(self.data).unwrap_or_else(|shared| shared.as_ref().clone())
    }

    /// Whether two tensors alias the same buffer (COW not yet triggered).
    /// Snapshot-isolation tests and pointer-keyed caches use this.
    pub fn shares_storage(&self, other: &HostTensor) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Scalar view of a rank-0/size-1 tensor.
    pub fn scalar(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("scalar() on tensor of {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Concatenate along axis 0. All tensors must share trailing dims.
    pub fn concat0(parts: &[HostTensor]) -> Result<HostTensor> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        let trailing = &first.shape[1..];
        let mut rows = 0;
        for p in parts {
            if &p.shape[1..] != trailing {
                bail!("concat0 trailing dims mismatch: {:?} vs {:?}", p.shape, first.shape);
            }
            rows += p.shape[0];
        }
        let mut shape = vec![rows];
        shape.extend_from_slice(trailing);
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(p.data());
        }
        HostTensor::new(shape, data)
    }

    /// Split along axis 0 into `n` equal chunks.
    pub fn split0(&self, n: usize) -> Result<Vec<HostTensor>> {
        let rows = self.shape[0];
        if rows % n != 0 {
            bail!("cannot split {} rows into {} chunks", rows, n);
        }
        let chunk_rows = rows / n;
        let stride: usize = self.shape[1..].iter().product();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut shape = self.shape.clone();
            shape[0] = chunk_rows;
            let lo = i * chunk_rows * stride;
            let hi = lo + chunk_rows * stride;
            out.push(HostTensor::new(shape, self.data[lo..hi].to_vec())?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_count() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros(&[4, 2]);
        assert_eq!(t.len(), 8);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn randn_stats() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(5);
        let t = HostTensor::randn(&[64, 64], 0.01, &mut rng);
        assert_eq!(t.shape(), &[64, 64]);
        let mean: f32 = t.data().iter().sum::<f32>() / t.len() as f32;
        let var: f32 =
            t.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < 0.001, "mean {mean}");
        assert!((var.sqrt() - 0.01).abs() < 0.002, "std {}", var.sqrt());
    }

    #[test]
    fn clone_shares_until_write() {
        let a = HostTensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let mut b = a.clone();
        assert!(a.shares_storage(&b), "clone must be a refcount bump");
        b.data_mut()[0] = 9.0;
        assert!(!a.shares_storage(&b), "first write must copy");
        assert_eq!(a.data(), &[1.0, 2.0, 3.0], "original untouched by COW");
        assert_eq!(b.data(), &[9.0, 2.0, 3.0]);
    }

    #[test]
    fn unshared_write_keeps_storage() {
        let mut a = HostTensor::zeros(&[4]);
        let p0 = a.data().as_ptr();
        a.data_mut()[1] = 1.0;
        assert_eq!(a.data().as_ptr(), p0, "sole owner mutates in place");
    }

    #[test]
    fn into_data_handles_sharing() {
        let a = HostTensor::new(vec![2], vec![5.0, 6.0]).unwrap();
        let b = a.clone();
        assert_eq!(a.into_data(), vec![5.0, 6.0]); // shared: copies
        assert_eq!(b.into_data(), vec![5.0, 6.0]); // sole owner: moves
    }

    #[test]
    fn concat_split_roundtrip() {
        let a = HostTensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        let b = HostTensor::new(vec![2, 3], (6..12).map(|x| x as f32).collect()).unwrap();
        let c = HostTensor::concat0(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(c.shape(), &[4, 3]);
        let parts = c.split0(2).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn split_uneven_errors() {
        let t = HostTensor::zeros(&[5, 2]);
        assert!(t.split0(2).is_err());
    }

    #[test]
    fn scalar_view() {
        let t = HostTensor::new(vec![1], vec![3.5]).unwrap();
        assert_eq!(t.scalar().unwrap(), 3.5);
        assert!(HostTensor::zeros(&[2]).scalar().is_err());
    }
}
