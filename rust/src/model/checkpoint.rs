//! Checkpoint format: a tiny self-describing binary container
//! (magic, n_conv, [v2: completed_steps,] tensor count, then per tensor:
//! rank, dims, f32 data). v2 (`OMNIVCK2`) adds the completed-step count
//! so a killed run can resume with the right remaining budget; v1 files
//! still load (steps = 0).
//!
//! Writes are atomic: the file is written to `<path>.tmp`, fsynced, and
//! renamed into place, so a crash mid-write never leaves a torn
//! checkpoint behind (DESIGN.md §Faults). Reads are hardened against
//! corrupt or hostile headers: rank, per-dim sizes, and the element
//! product are all capped and checked against the remaining file length
//! *before* any allocation.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ParamSet;
use crate::tensor::HostTensor;

const MAGIC_V1: &[u8; 8] = b"OMNIVCK1";
const MAGIC_V2: &[u8; 8] = b"OMNIVCK2";

/// Sanity caps for parsed headers: no real tensor in this repo comes
/// close (caffenet8 FC weights are ~38M elements).
const MAX_RANK: usize = 8;
const MAX_DIM: usize = 1 << 31;
const MAX_TENSORS: usize = 1 << 16;

/// Serialize a ParamSet to `path` (v2 layout, steps = 0). Atomic.
pub fn save_checkpoint(params: &ParamSet, path: &Path) -> Result<()> {
    save_checkpoint_at(params, 0, path)
}

/// Serialize a ParamSet plus the number of completed optimizer steps to
/// `path`, atomically (tmp + fsync + rename).
pub fn save_checkpoint_at(params: &ParamSet, completed_steps: u64, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating checkpoint dir {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating checkpoint {}", tmp.display()))?;
        f.write_all(MAGIC_V2)?;
        f.write_all(&(params.n_conv() as u64).to_le_bytes())?;
        f.write_all(&completed_steps.to_le_bytes())?;
        f.write_all(&(params.tensors().len() as u64).to_le_bytes())?;
        for t in params.tensors() {
            f.write_all(&(t.shape().len() as u64).to_le_bytes())?;
            for &d in t.shape() {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(crate::tensor::f32_bytes(t.data()))?;
        }
        f.sync_all().with_context(|| format!("fsyncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Load a ParamSet from `path` (v1 or v2; step count discarded).
pub fn load_checkpoint(path: &Path) -> Result<ParamSet> {
    load_checkpoint_state(path).map(|(p, _)| p)
}

/// Load a ParamSet and the completed-step count it was saved at
/// (0 for v1 files, which predate the field).
pub fn load_checkpoint_state(path: &Path) -> Result<(ParamSet, u64)> {
    let file_len = std::fs::metadata(path)
        .with_context(|| format!("stat checkpoint {}", path.display()))?
        .len();
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    let v2 = match &magic {
        m if m == MAGIC_V1 => false,
        m if m == MAGIC_V2 => true,
        _ => bail!("{} is not an omnivore checkpoint", path.display()),
    };
    fn next_u64(f: &mut std::fs::File, consumed: &mut u64) -> Result<u64> {
        *consumed += 8;
        read_u64(f)
    }
    let mut consumed = 8u64;
    let n_conv = next_u64(&mut f, &mut consumed)? as usize;
    let completed_steps = if v2 { next_u64(&mut f, &mut consumed)? } else { 0 };
    let n_tensors = next_u64(&mut f, &mut consumed)? as usize;
    if n_tensors > MAX_TENSORS {
        bail!("checkpoint claims {n_tensors} tensors (cap {MAX_TENSORS}); corrupt header");
    }
    let mut tensors = Vec::with_capacity(n_tensors.min(1024));
    for i in 0..n_tensors {
        let rank = next_u64(&mut f, &mut consumed)? as usize;
        if rank > MAX_RANK {
            bail!("tensor {i}: rank {rank} exceeds cap {MAX_RANK}; corrupt header");
        }
        let mut shape = Vec::with_capacity(rank);
        let mut n: usize = 1;
        for _ in 0..rank {
            let d = next_u64(&mut f, &mut consumed)? as usize;
            if d > MAX_DIM {
                bail!("tensor {i}: dim {d} exceeds cap {MAX_DIM}; corrupt header");
            }
            n = n
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("tensor {i}: element count overflows"))?;
            shape.push(d);
        }
        let data_bytes = (n as u64)
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("tensor {i}: byte count overflows"))?;
        // The claimed payload must fit in what's left of the file —
        // checked BEFORE allocating, so a garbage header can't drive an
        // unbounded allocation.
        if data_bytes > file_len.saturating_sub(consumed) {
            bail!(
                "tensor {i}: claims {data_bytes} data bytes but only {} remain in {}",
                file_len.saturating_sub(consumed),
                path.display()
            );
        }
        let mut bytes = vec![0u8; data_bytes as usize];
        f.read_exact(&mut bytes)?;
        consumed += data_bytes;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push(HostTensor::new(shape, data)?);
    }
    Ok((ParamSet::from_tensors(tensors, n_conv)?, completed_steps))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ParamSet {
        let t1 = HostTensor::new(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]).unwrap();
        let t2 = HostTensor::new(vec![3], vec![9.0, 8.0, 7.0]).unwrap();
        ParamSet::from_tensors(vec![t1, t2], 1).unwrap()
    }

    #[test]
    fn roundtrip() {
        let p = params();
        let dir = crate::util::temp_dir("ckpt").unwrap();
        let path = dir.join("ck.bin");
        save_checkpoint(&p, &path).unwrap();
        let p2 = load_checkpoint(&path).unwrap();
        assert_eq!(p, p2);
        assert_eq!(p2.n_conv(), 1);
        // No .tmp left behind after the rename.
        assert!(!path.with_extension("tmp").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn roundtrip_with_steps_and_nested_dir() {
        let p = params();
        let dir = crate::util::temp_dir("ckpt-v2").unwrap();
        let path = dir.join("deep/nested/ck.bin");
        save_checkpoint_at(&p, 42, &path).unwrap();
        let (p2, steps) = load_checkpoint_state(&path).unwrap();
        assert_eq!(p, p2);
        assert_eq!(steps, 42);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn loads_legacy_v1_with_zero_steps() {
        let p = params();
        let dir = crate::util::temp_dir("ckpt-v1").unwrap();
        let path = dir.join("ck.bin");
        // Hand-write a v1 file (the old layout, no step count).
        let mut buf: Vec<u8> = MAGIC_V1.to_vec();
        buf.extend((p.n_conv() as u64).to_le_bytes());
        buf.extend((p.tensors().len() as u64).to_le_bytes());
        for t in p.tensors() {
            buf.extend((t.shape().len() as u64).to_le_bytes());
            for &d in t.shape() {
                buf.extend((d as u64).to_le_bytes());
            }
            for &x in t.data() {
                buf.extend(x.to_le_bytes());
            }
        }
        std::fs::write(&path, buf).unwrap();
        let (p2, steps) = load_checkpoint_state(&path).unwrap();
        assert_eq!(p, p2);
        assert_eq!(steps, 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::temp_dir("ckpt-bad").unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"notacheckpointfile").unwrap();
        assert!(load_checkpoint(&path).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_hostile_headers_before_allocating() {
        let dir = crate::util::temp_dir("ckpt-hostile").unwrap();

        // Claims one rank-1 tensor of 2^60 elements in a 50-byte file:
        // the old loader would try to allocate 2^62 bytes.
        let mut huge: Vec<u8> = MAGIC_V2.to_vec();
        huge.extend(1u64.to_le_bytes()); // n_conv
        huge.extend(0u64.to_le_bytes()); // steps
        huge.extend(1u64.to_le_bytes()); // n_tensors
        huge.extend(1u64.to_le_bytes()); // rank
        huge.extend((1u64 << 60).to_le_bytes()); // dim
        let p = dir.join("huge.bin");
        std::fs::write(&p, &huge).unwrap();
        let err = load_checkpoint(&p).unwrap_err().to_string();
        assert!(err.contains("cap") || err.contains("remain"), "{err}");

        // Absurd rank.
        let mut ranky: Vec<u8> = MAGIC_V2.to_vec();
        ranky.extend(1u64.to_le_bytes());
        ranky.extend(0u64.to_le_bytes());
        ranky.extend(1u64.to_le_bytes());
        ranky.extend(10_000u64.to_le_bytes()); // rank
        let p = dir.join("ranky.bin");
        std::fs::write(&p, &ranky).unwrap();
        assert!(load_checkpoint(&p).unwrap_err().to_string().contains("rank"));

        // Modest dims whose product still exceeds the file length.
        let mut short: Vec<u8> = MAGIC_V2.to_vec();
        short.extend(1u64.to_le_bytes());
        short.extend(0u64.to_le_bytes());
        short.extend(1u64.to_le_bytes());
        short.extend(2u64.to_le_bytes()); // rank 2
        short.extend(1000u64.to_le_bytes());
        short.extend(1000u64.to_le_bytes());
        short.extend([0u8; 16]); // only 16 data bytes, not 4M
        let p = dir.join("short.bin");
        std::fs::write(&p, &short).unwrap();
        assert!(load_checkpoint(&p).unwrap_err().to_string().contains("remain"));

        let _ = std::fs::remove_dir_all(dir);
    }
}
