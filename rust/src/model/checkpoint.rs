//! Checkpoint format: a tiny self-describing binary container
//! (magic, n_conv, tensor count, then per tensor: rank, dims, f32 data).
//! Written at every optimizer epoch boundary (Algorithm 1 line 8).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ParamSet;
use crate::tensor::HostTensor;

const MAGIC: &[u8; 8] = b"OMNIVCK1";

/// Serialize a ParamSet to `path`.
pub fn save_checkpoint(params: &ParamSet, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating checkpoint {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.n_conv() as u64).to_le_bytes())?;
    f.write_all(&(params.tensors().len() as u64).to_le_bytes())?;
    for t in params.tensors() {
        f.write_all(&(t.shape().len() as u64).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

/// Load a ParamSet from `path`.
pub fn load_checkpoint(path: &Path) -> Result<ParamSet> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an omnivore checkpoint", path.display());
    }
    let n_conv = read_u64(&mut f)? as usize;
    let n_tensors = read_u64(&mut f)? as usize;
    let mut tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let rank = read_u64(&mut f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut f)? as usize);
        }
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push(HostTensor::new(shape, data)?);
    }
    ParamSet::from_tensors(tensors, n_conv)
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t1 = HostTensor::new(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]).unwrap();
        let t2 = HostTensor::new(vec![3], vec![9.0, 8.0, 7.0]).unwrap();
        let p = ParamSet::from_tensors(vec![t1, t2], 1).unwrap();
        let dir = crate::util::temp_dir("ckpt").unwrap();
        let path = dir.join("ck.bin");
        save_checkpoint(&p, &path).unwrap();
        let p2 = load_checkpoint(&path).unwrap();
        assert_eq!(p, p2);
        assert_eq!(p2.n_conv(), 1);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::temp_dir("ckpt-bad").unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"notacheckpointfile").unwrap();
        assert!(load_checkpoint(&path).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
