//! Model parameter schema: the CNN's weights W = {W_conv, W_fc} as host
//! tensors, split along the paper's two-phase boundary (conv phase models
//! are small, FC phase models are large — Fig 1 / §II-C). Initialization
//! matches the experiment setup in Appendix F-B (Gaussian 0/0.01 weights,
//! zero biases). Checkpointing is the optimizer's epoch boundary
//! (Algorithm 1 line 8: "the model is checkpointed").

mod checkpoint;

pub use checkpoint::{
    load_checkpoint, load_checkpoint_state, save_checkpoint, save_checkpoint_at,
};

use anyhow::Result;

use crate::runtime::ArchInfo;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

/// All parameters of a two-phase CNN, conv phase first.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    tensors: Vec<HostTensor>,
    n_conv: usize,
}

impl ParamSet {
    /// Gaussian init std. The paper uses 0.01 for full-size CaffeNet; at
    /// this repo's scaled dimensions 0.05 approximates He fan-in scaling
    /// and avoids a needlessly long cold-start plateau (see DESIGN.md).
    /// Must match python model.INIT_STD.
    pub const INIT_STD: f32 = 0.05;

    /// Paper-protocol init: weights ~ N(0, INIT_STD), biases 0.
    pub fn init(arch: &ArchInfo, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let tensors = arch
            .params
            .iter()
            .map(|p| {
                if p.name.starts_with('w') {
                    HostTensor::randn(&p.shape, Self::INIT_STD, &mut rng)
                } else {
                    HostTensor::zeros(&p.shape)
                }
            })
            .collect();
        Self { tensors, n_conv: arch.n_conv_params }
    }

    /// Zeros with the same schema (velocity / gradient accumulators).
    pub fn zeros_like(other: &ParamSet) -> Self {
        Self {
            tensors: other.tensors.iter().map(|t| HostTensor::zeros(t.shape())).collect(),
            n_conv: other.n_conv,
        }
    }

    pub fn from_tensors(tensors: Vec<HostTensor>, n_conv: usize) -> Result<Self> {
        anyhow::ensure!(n_conv <= tensors.len(), "n_conv out of range");
        Ok(Self { tensors, n_conv })
    }

    pub fn tensors(&self) -> &[HostTensor] {
        &self.tensors
    }

    pub fn tensors_mut(&mut self) -> &mut [HostTensor] {
        &mut self.tensors
    }

    pub fn n_conv(&self) -> usize {
        self.n_conv
    }

    /// Conv-phase parameters (small model, goes over the network).
    pub fn conv(&self) -> &[HostTensor] {
        &self.tensors[..self.n_conv]
    }

    /// FC-phase parameters (large model, pinned to the merged FC server).
    pub fn fc(&self) -> &[HostTensor] {
        &self.tensors[self.n_conv..]
    }

    /// Split into (conv, fc) halves, consuming self.
    pub fn split(mut self) -> (Vec<HostTensor>, Vec<HostTensor>) {
        let fc = self.tensors.split_off(self.n_conv);
        (self.tensors, fc)
    }

    /// Total scalar parameter count.
    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Flattened view for norm/diagnostic computations.
    pub fn flat_iter(&self) -> impl Iterator<Item = f32> + '_ {
        self.tensors.iter().flat_map(|t| t.data().iter().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn tiny_arch() -> ArchInfo {
        ArchInfo::from_json(
            &crate::util::json::Json::parse(
                r#"{"input":[8,8,1],"ncls":2,"feat":32,"k":3,
                "params":[{"name":"wc1","shape":[3,3,1,4]},{"name":"bc1","shape":[4]},
                          {"name":"wf1","shape":[32,2]},{"name":"bf1","shape":[2]}],
                "n_conv_params":2,"conv_bytes":160,"fc_bytes":264}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn init_schema() {
        let arch = tiny_arch();
        let p = ParamSet::init(&arch, 7);
        assert_eq!(p.tensors().len(), 4);
        assert_eq!(p.conv().len(), 2);
        assert_eq!(p.fc().len(), 2);
        assert_eq!(p.num_params(), 36 + 4 + 64 + 2);
        // biases zero, weights not all zero
        assert!(p.tensors()[1].data().iter().all(|&x| x == 0.0));
        assert!(p.tensors()[0].data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn init_deterministic() {
        let arch = tiny_arch();
        assert_eq!(ParamSet::init(&arch, 3), ParamSet::init(&arch, 3));
        assert_ne!(ParamSet::init(&arch, 3), ParamSet::init(&arch, 4));
    }

    #[test]
    fn zeros_like_matches() {
        let arch = tiny_arch();
        let p = ParamSet::init(&arch, 0);
        let z = ParamSet::zeros_like(&p);
        assert_eq!(z.num_params(), p.num_params());
        assert!(z.flat_iter().all(|x| x == 0.0));
    }

    #[test]
    fn split_halves() {
        let arch = tiny_arch();
        let p = ParamSet::init(&arch, 0);
        let (c, f) = p.split();
        assert_eq!(c.len(), 2);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn param_spec_shapes_flow_through() {
        let arch = tiny_arch();
        assert_eq!(arch.params[0].shape, vec![3, 3, 1, 4]);
        assert_eq!(arch.params[0].name, "wc1");
    }
}
