//! Native CPU kernels for the four hot ops (paper §III) — blocked/tiled
//! f32 GEMM, conv via im2col lowering with the `b_p` batching knob,
//! 2x2 max-pool, and fused softmax + cross-entropy — pure functions over
//! `&[f32]` slices so the [`super::NativeBackend`], the benches, and the
//! parity tests all drive exactly the same code.
//!
//! Ports of `python/compile/kernels/{gemm,conv_gemm,pool,softmax_xent}.py`
//! with the paper's CPU schedule instead of the Pallas/TPU one:
//!
//! * GEMM is **C-tile stationary**: for each (i, j) output tile, the
//!   accumulator tile stays hot while the k loop streams A/B stripes —
//!   the OpenBLAS cache-blocking shape the paper assumes (§III-A).
//! * Tiles come from [`pick_tile`]'s near-equal split, so ragged shapes
//!   (K = 800 with max 512 -> 2x400) never pad (python gemm.py).
//! * Row-panel parallelism via `std::thread::scope`: threads own disjoint
//!   row ranges of C, so there is no reduction race and the result is
//!   **bitwise invariant to thread count, tile sizes, and `b_p`** — each
//!   output element always accumulates in ascending-k order.
//! * Conv lowers all `b_p` images into one D-hat and runs ONE large GEMM
//!   per chunk (paper Fig 2): `b_p = b` is the CPU strategy (max tile
//!   utilization, b x the lowering memory), `b_p = 1` the GPU/Caffe
//!   strategy (Fig 4's tradeoff).

/// Round `x` up to a multiple of `m`.
fn ceil_to(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Largest 8-aligned tile <= `max_tile` that splits `n` evenly-ish.
///
/// Naive `min(max_tile, n)` pads the last tile: K=800 with max 512 ->
/// tiles of 512 + 288 (21.9% wasted MACs against a 512 accumulator).
/// Splitting into ceil(n/max_tile) near-equal tiles (800 -> 2x400)
/// eliminates the waste. Must match python/compile/kernels/gemm.py.
pub fn pick_tile(n: usize, max_tile: usize) -> usize {
    if n <= max_tile {
        return ceil_to(n.max(1), 8);
    }
    let n_tiles = n.div_ceil(max_tile);
    ceil_to(n.div_ceil(n_tiles), 8)
}

/// Blocked-GEMM schedule knobs. Defaults match the python kernels
/// (`DEFAULT_BM/BN/BK`); `threads` defaults to the host parallelism.
#[derive(Clone, Copy, Debug)]
pub struct GemmParams {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
    pub threads: usize,
}

impl Default for GemmParams {
    fn default() -> Self {
        Self { bm: 128, bn: 128, bk: 512, threads: default_threads() }
    }
}

impl GemmParams {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads: threads.max(1), ..Self::default() }
    }
}

/// Worker threads for kernel row panels: `OMNIVORE_THREADS` if set, else
/// the host's available parallelism, capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("OMNIVORE_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 16)
}

/// Run `f` over `rows` split into at most `threads` contiguous row
/// panels of `c` (row width `cols`). Each panel is a disjoint `&mut`
/// slice, so the scoped threads never race; panel boundaries do not
/// change any output element's accumulation order.
fn par_row_panels<F>(c: &mut [f32], rows: usize, cols: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(c.len(), rows * cols);
    // At least 8 rows per panel: tiny panels cost more to spawn than run.
    let t = threads.max(1).min(rows.div_ceil(8)).max(1);
    if t <= 1 {
        f(0, rows, c);
        return;
    }
    let base = rows / t;
    let extra = rows % t;
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest = c;
        let mut row0 = 0usize;
        for i in 0..t {
            let take = base + usize::from(i < extra);
            let (panel, tail) = rest.split_at_mut(take * cols);
            rest = tail;
            s.spawn(move || fr(row0, take, panel));
            row0 += take;
        }
    });
}

/// C = A @ B into `c`: a [m,k] row-major, b [k,n] row-major, c [m,n].
///
/// C-tile-stationary blocked schedule over [`pick_tile`] tiles with
/// row-panel threading. Every `c[i,j]` accumulates `a[i,kk]*b[kk,j]` in
/// ascending-kk order regardless of tiling or thread count, so the
/// result is bitwise identical across schedules.
pub fn gemm_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, p: &GemmParams) {
    assert_eq!(a.len(), m * k, "gemm: A shape");
    assert_eq!(b.len(), k * n, "gemm: B shape");
    assert_eq!(c.len(), m * n, "gemm: C shape");
    if m == 0 || n == 0 {
        return;
    }
    let threads = if 2 * m * k * n < (1 << 16) { 1 } else { p.threads };
    let tn = pick_tile(n, p.bn).min(n.max(1));
    let tk = pick_tile(k.max(1), p.bk);
    par_row_panels(c, m, n, threads, |row0, nrows, panel| {
        let tm = pick_tile(nrows, p.bm);
        let mut acc = vec![0f32; tm * tn];
        let mut i0 = 0;
        while i0 < nrows {
            let il = tm.min(nrows - i0);
            let mut j0 = 0;
            while j0 < n {
                let jl = tn.min(n - j0);
                acc[..il * jl].iter_mut().for_each(|v| *v = 0.0);
                let mut k0 = 0;
                while k0 < k {
                    let kl = tk.min(k - k0);
                    for ii in 0..il {
                        let arow = &a[(row0 + i0 + ii) * k + k0..][..kl];
                        let crow = &mut acc[ii * jl..][..jl];
                        for (kk, &av) in arow.iter().enumerate() {
                            let brow = &b[(k0 + kk) * n + j0..][..jl];
                            for (cv, &bv) in crow.iter_mut().zip(brow) {
                                *cv += av * bv;
                            }
                        }
                    }
                    k0 += kl;
                }
                for ii in 0..il {
                    panel[(i0 + ii) * n + j0..][..jl]
                        .copy_from_slice(&acc[ii * jl..][..jl]);
                }
                j0 += jl;
            }
            i0 += il;
        }
    });
}

/// Allocating wrapper over [`gemm_into`].
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, p: &GemmParams) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_into(&mut c, a, b, m, k, n, p);
    c
}

/// C += A^T @ B: a [p_rows, m], b [p_rows, n], c [m, n] accumulated IN
/// PLACE in ascending-p order (weight gradients: D-hat^T @ g-hat). The
/// in-place, p-ascending accumulation makes chunked callers (conv wgrad
/// over `b_p` chunks) bitwise independent of the chunking.
pub fn gemm_tn_acc(c: &mut [f32], a: &[f32], b: &[f32], p_rows: usize, m: usize, n: usize, threads: usize) {
    assert_eq!(a.len(), p_rows * m, "gemm_tn: A shape");
    assert_eq!(b.len(), p_rows * n, "gemm_tn: B shape");
    assert_eq!(c.len(), m * n, "gemm_tn: C shape");
    let threads = if 2 * p_rows * m * n < (1 << 16) { 1 } else { threads };
    par_row_panels(c, m, n, threads, |row0, nrows, panel| {
        for pp in 0..p_rows {
            let brow = &b[pp * n..][..n];
            for ii in 0..nrows {
                let av = a[pp * m + row0 + ii];
                if av != 0.0 {
                    let crow = &mut panel[ii * n..][..n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    });
}

/// C = A @ B^T: a [m,k], b [n,k], c [m,n] (activation gradients:
/// `g @ W^T` without materializing the transpose). Row-wise dot products
/// accumulate in ascending-k order.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "gemm_nt: A shape");
    assert_eq!(b.len(), n * k, "gemm_nt: B shape");
    let mut c = vec![0f32; m * n];
    let threads = if 2 * m * k * n < (1 << 16) { 1 } else { threads };
    par_row_panels(&mut c, m, n, threads, |row0, nrows, panel| {
        for ii in 0..nrows {
            let arow = &a[(row0 + ii) * k..][..k];
            let crow = &mut panel[ii * n..][..n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..][..k];
                let mut s = 0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    s += av * bv;
                }
                *cv = s;
            }
        }
    });
    c
}

/// Lowering step (paper Fig 2): write D-hat rows for `b` NHWC images
/// into `dhat` ([b*h*w, kh*kw*cin], (kh, kw, cin) row-major — matching
/// `im2col_ref` / the HWIO weight reshape). SAME padding, stride 1, odd
/// kernels. Every element of `dhat` is written (padding zones zeroed).
pub fn im2col_into(dhat: &mut [f32], x: &[f32], b: usize, h: usize, w: usize, cin: usize, kh: usize, kw: usize) {
    let kkc = kh * kw * cin;
    assert_eq!(dhat.len(), b * h * w * kkc, "im2col: D-hat shape");
    assert_eq!(x.len(), b * h * w * cin, "im2col: x shape");
    let (ph, pw) = (kh / 2, kw / 2);
    for img in 0..b {
        let xi = &x[img * h * w * cin..][..h * w * cin];
        for y in 0..h {
            for xw in 0..w {
                let drow = &mut dhat[((img * h + y) * w + xw) * kkc..][..kkc];
                for ki in 0..kh {
                    let iy = (y + ki).wrapping_sub(ph);
                    for kj in 0..kw {
                        let ix = (xw + kj).wrapping_sub(pw);
                        let dst = &mut drow[(ki * kw + kj) * cin..][..cin];
                        if iy < h && ix < w {
                            dst.copy_from_slice(&xi[(iy * w + ix) * cin..][..cin]);
                        } else {
                            dst.iter_mut().for_each(|v| *v = 0.0);
                        }
                    }
                }
            }
        }
    }
}

/// Normalize the `b_p` knob: 0 (or > b) means the paper's CPU pick
/// `b_p = b`; a non-divisor falls back to the largest divisor of `b`
/// below it (the python kernel asserts instead; the runtime must not).
pub fn normalize_bp(b: usize, b_p: usize) -> usize {
    if b_p == 0 || b_p >= b {
        return b.max(1);
    }
    let mut bp = b_p;
    while b % bp != 0 {
        bp -= 1;
    }
    bp
}

/// SAME stride-1 conv via lowering + batched GEMM (paper §III, Fig 2).
/// x [b,h,w,cin], w [kh,kw,cin,cout] (HWIO) -> [b,h,w,cout].
///
/// `b_p` images are lowered per chunk into one D-hat feeding ONE GEMM of
/// `b_p*h*w` rows; the result is bitwise b_p-invariant (each output row
/// belongs to exactly one image) — only the schedule and the D-hat
/// footprint (`4*b_p*h*w*kh*kw*cin` bytes) change.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same(x: &[f32], wt: &[f32], b: usize, h: usize, w: usize, cin: usize, kh: usize, kw: usize, cout: usize, b_p: usize, p: &GemmParams) -> Vec<f32> {
    assert_eq!(x.len(), b * h * w * cin, "conv: x shape");
    assert_eq!(wt.len(), kh * kw * cin * cout, "conv: w shape");
    let b_p = normalize_bp(b, b_p);
    let kkc = kh * kw * cin;
    let rows = b_p * h * w;
    let mut out = vec![0f32; b * h * w * cout];
    let mut dhat = vec![0f32; rows * kkc];
    let mut c0 = 0;
    while c0 < b {
        im2col_into(&mut dhat, &x[c0 * h * w * cin..][..b_p * h * w * cin], b_p, h, w, cin, kh, kw);
        gemm_into(&mut out[c0 * h * w * cout..][..rows * cout], &dhat, wt, rows, kkc, cout, p);
        c0 += b_p;
    }
    out
}

/// dL/dw for SAME stride-1 conv as chunked `D-hat^T @ g-hat` GEMMs
/// (the paper's lowering applied to the backward pass). x [b,h,w,cin],
/// g [b,h,w,cout] -> [kh,kw,cin,cout] flat. In-place p-ascending
/// accumulation keeps the result bitwise b_p-invariant.
#[allow(clippy::too_many_arguments)]
pub fn conv_wgrad(x: &[f32], g: &[f32], b: usize, h: usize, w: usize, cin: usize, kh: usize, kw: usize, cout: usize, b_p: usize, p: &GemmParams) -> Vec<f32> {
    assert_eq!(x.len(), b * h * w * cin, "wgrad: x shape");
    assert_eq!(g.len(), b * h * w * cout, "wgrad: g shape");
    let b_p = normalize_bp(b, b_p);
    let kkc = kh * kw * cin;
    let rows = b_p * h * w;
    let mut gw = vec![0f32; kkc * cout];
    let mut dhat = vec![0f32; rows * kkc];
    let mut c0 = 0;
    while c0 < b {
        im2col_into(&mut dhat, &x[c0 * h * w * cin..][..rows * cin], b_p, h, w, cin, kh, kw);
        let ghat = &g[c0 * h * w * cout..][..rows * cout];
        gemm_tn_acc(&mut gw, &dhat, ghat, rows, kkc, cout, p.threads);
        c0 += b_p;
    }
    gw
}

/// HWIO kernel -> 180-degree-rotated, in/out-swapped kernel for the
/// input-gradient conv (`_flip_w` in python model.py):
/// out[i,j,o,c] = w[kh-1-i, kw-1-j, c, o]. Returns [kh,kw,cout,cin] flat.
pub fn flip_w(wt: &[f32], kh: usize, kw: usize, cin: usize, cout: usize) -> Vec<f32> {
    assert_eq!(wt.len(), kh * kw * cin * cout, "flip_w: shape");
    let mut out = vec![0f32; kh * kw * cout * cin];
    for i in 0..kh {
        for j in 0..kw {
            for c in 0..cin {
                for o in 0..cout {
                    out[((i * kw + j) * cout + o) * cin + c] =
                        wt[(((kh - 1 - i) * kw + (kw - 1 - j)) * cin + c) * cout + o];
                }
            }
        }
    }
    out
}

/// 2x2 stride-2 max pool. x [b,h,w,c] (h, w even) -> [b,h/2,w/2,c].
pub fn maxpool2x2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    assert_eq!(x.len(), b * h * w * c, "pool: x shape");
    assert!(h % 2 == 0 && w % 2 == 0, "pool: odd spatial dims");
    let (h2, w2) = (h / 2, w / 2);
    let mut out = vec![0f32; b * h2 * w2 * c];
    for img in 0..b {
        for y in 0..h2 {
            for xw in 0..w2 {
                let orow = &mut out[((img * h2 + y) * w2 + xw) * c..][..c];
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let irow = &x[((img * h + 2 * y + dy) * w + 2 * xw + dx) * c..][..c];
                    if dy == 0 && dx == 0 {
                        orow.copy_from_slice(irow);
                    } else {
                        for (o, &v) in orow.iter_mut().zip(irow) {
                            if v > *o {
                                *o = v;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Max-pool backward: route pooled grads to max positions; ties (exact
/// float equality) receive the gradient in every tied position — the
/// `gu * (x == yu)` rule of python model.py `_maxpool_bwd`.
pub fn maxpool2x2_bwd(x: &[f32], y: &[f32], g: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let (h2, w2) = (h / 2, w / 2);
    assert_eq!(x.len(), b * h * w * c, "pool_bwd: x shape");
    assert_eq!(y.len(), b * h2 * w2 * c, "pool_bwd: y shape");
    assert_eq!(g.len(), y.len(), "pool_bwd: g shape");
    let mut out = vec![0f32; x.len()];
    for img in 0..b {
        for yy in 0..h2 {
            for xw in 0..w2 {
                let base = ((img * h2 + yy) * w2 + xw) * c;
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let idx = ((img * h + 2 * yy + dy) * w + 2 * xw + dx) * c;
                    for cc in 0..c {
                        if x[idx + cc] == y[base + cc] {
                            out[idx + cc] = g[base + cc];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Fused softmax + cross-entropy: logits [b,n], labels [b] ->
/// (mean loss, accuracy, grad [b,n] already divided by b). Matches
/// `softmax_xent_ref`: max-subtracted logsumexp, first-occurrence argmax.
pub fn softmax_xent(logits: &[f32], labels: &[i32], b: usize, n: usize) -> (f32, f32, Vec<f32>) {
    assert_eq!(logits.len(), b * n, "xent: logits shape");
    assert_eq!(labels.len(), b, "xent: labels shape");
    let mut grad = vec![0f32; b * n];
    let mut loss = 0f64;
    let mut correct = 0usize;
    for i in 0..b {
        let row = &logits[i * n..][..n];
        let mut zmax = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &z) in row.iter().enumerate() {
            if z > zmax {
                zmax = z;
                argmax = j;
            }
        }
        let mut sum = 0f32;
        for &z in row {
            sum += (z - zmax).exp();
        }
        let lse = sum.ln();
        let y = labels[i] as usize;
        loss += (lse - (row[y] - zmax)) as f64;
        if argmax == y {
            correct += 1;
        }
        let grow = &mut grad[i * n..][..n];
        for (j, gz) in grow.iter_mut().enumerate() {
            let p = ((row[j] - zmax) - lse).exp();
            let onehot = if j == y { 1.0 } else { 0.0 };
            *gz = (p - onehot) / b as f32;
        }
    }
    ((loss / b as f64) as f32, correct as f32 / b as f32, grad)
}

/// y += bias broadcast over rows: y [rows, c], bias [c].
pub fn bias_add(y: &mut [f32], bias: &[f32], rows: usize, c: usize) {
    assert_eq!(y.len(), rows * c, "bias_add: y shape");
    assert_eq!(bias.len(), c, "bias_add: bias shape");
    for r in 0..rows {
        for (v, &bv) in y[r * c..][..c].iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

/// ReLU in place.
pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// g *= (z > 0): ReLU backward mask.
pub fn relu_bwd_inplace(g: &mut [f32], z: &[f32]) {
    assert_eq!(g.len(), z.len(), "relu_bwd: shape");
    for (gv, &zv) in g.iter_mut().zip(z) {
        if zv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Column sums: x [rows, c] -> [c] (bias gradients).
pub fn colsum(x: &[f32], rows: usize, c: usize) -> Vec<f32> {
    assert_eq!(x.len(), rows * c, "colsum: shape");
    let mut out = vec![0f32; c];
    for r in 0..rows {
        for (o, &v) in out.iter_mut().zip(&x[r * c..][..c]) {
            *o += v;
        }
    }
    out
}

/// D-hat footprint in bytes at a given `b_p` (paper Fig 4c memory curve).
pub fn lowered_bytes(b_p: usize, h: usize, w: usize, kh: usize, kw: usize, cin: usize) -> usize {
    4 * b_p * h * w * kh * kw * cin
}

/// FLOP count of a SAME conv as GFLOP (2 MACs per multiply-add).
pub fn conv_gflops(b: usize, h: usize, w: usize, kh: usize, kw: usize, cin: usize, cout: usize) -> f64 {
    2.0 * (b * h * w) as f64 * cout as f64 * (kh * kw * cin) as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn gemm_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn pick_tile_near_equal_split() {
        // The documented 800/512 case: 2 tiles of 400, NOT 512 + 288.
        assert_eq!(pick_tile(800, 512), 400);
        // <= max: round up to 8.
        assert_eq!(pick_tile(10, 128), 16);
        assert_eq!(pick_tile(512, 512), 512);
        assert_eq!(pick_tile(128, 128), 128);
        // 1000 -> 2 tiles -> 500 -> 504 (8-aligned), covering in 504+496.
        assert_eq!(pick_tile(1000, 512), 504);
        assert_eq!(pick_tile(1, 128), 8);
    }

    #[test]
    fn gemm_matches_naive_ragged() {
        // Ragged in every dimension (not multiples of any tile).
        let (m, k, n) = (13, 57, 9);
        let a = randv(m * k, 1);
        let b = randv(k * n, 2);
        let c = gemm(&a, &b, m, k, n, &GemmParams::with_threads(1));
        let want = gemm_naive(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_invariant_to_threads_and_tiles() {
        let (m, k, n) = (64, 800, 24);
        let a = randv(m * k, 3);
        let b = randv(k * n, 4);
        let base = gemm(&a, &b, m, k, n, &GemmParams { bm: 128, bn: 128, bk: 512, threads: 1 });
        for threads in [2, 4, 7] {
            for (bm, bn, bk) in [(128, 128, 512), (32, 16, 64), (8, 8, 8), (256, 256, 1024)] {
                let c = gemm(&a, &b, m, k, n, &GemmParams { bm, bn, bk, threads });
                assert_eq!(c, base, "threads={threads} tiles=({bm},{bn},{bk})");
            }
        }
    }

    #[test]
    fn gemm_tn_and_nt_match_naive() {
        let (p, m, n) = (17, 11, 7);
        let a = randv(p * m, 5); // [p, m]
        let b = randv(p * n, 6); // [p, n]
        let mut c = vec![0f32; m * n];
        gemm_tn_acc(&mut c, &a, &b, p, m, n, 1);
        // naive A^T @ B
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f32;
                for pp in 0..p {
                    s += a[pp * m + i] * b[pp * n + j];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4);
            }
        }
        let (m2, k2, n2) = (9, 13, 5);
        let a2 = randv(m2 * k2, 7);
        let b2 = randv(n2 * k2, 8); // [n, k]
        let c2 = gemm_nt(&a2, &b2, m2, k2, n2, 1);
        for i in 0..m2 {
            for j in 0..n2 {
                let mut s = 0f32;
                for kk in 0..k2 {
                    s += a2[i * k2 + kk] * b2[j * k2 + kk];
                }
                assert!((c2[i * n2 + j] - s).abs() < 1e-4);
            }
        }
    }

    fn conv_naive(x: &[f32], wt: &[f32], b: usize, h: usize, w: usize, cin: usize, kh: usize, kw: usize, cout: usize) -> Vec<f32> {
        let (ph, pw) = (kh / 2, kw / 2);
        let mut out = vec![0f32; b * h * w * cout];
        for img in 0..b {
            for y in 0..h {
                for xw in 0..w {
                    for o in 0..cout {
                        let mut s = 0f32;
                        for ki in 0..kh {
                            for kj in 0..kw {
                                let iy = (y + ki).wrapping_sub(ph);
                                let ix = (xw + kj).wrapping_sub(pw);
                                if iy < h && ix < w {
                                    for c in 0..cin {
                                        s += x[((img * h + iy) * w + ix) * cin + c]
                                            * wt[((ki * kw + kj) * cin + c) * cout + o];
                                    }
                                }
                            }
                        }
                        out[((img * h + y) * w + xw) * cout + o] = s;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_naive_and_is_bp_invariant() {
        let (b, h, w, cin, kh, kw, cout) = (4, 6, 6, 3, 3, 3, 5);
        let x = randv(b * h * w * cin, 9);
        let wt = randv(kh * kw * cin * cout, 10);
        let p = GemmParams::with_threads(2);
        let want = conv_naive(&x, &wt, b, h, w, cin, kh, kw, cout);
        let full = conv2d_same(&x, &wt, b, h, w, cin, kh, kw, cout, b, &p);
        for (a, e) in full.iter().zip(&want) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
        for bp in [1, 2, 4, 0, 99] {
            let y = conv2d_same(&x, &wt, b, h, w, cin, kh, kw, cout, bp, &p);
            assert_eq!(y, full, "b_p={bp} must be bitwise invariant");
        }
    }

    #[test]
    fn wgrad_is_bp_invariant() {
        let (b, h, w, cin, kh, kw, cout) = (4, 4, 4, 2, 3, 3, 3);
        let x = randv(b * h * w * cin, 11);
        let g = randv(b * h * w * cout, 12);
        let p = GemmParams::with_threads(1);
        let full = conv_wgrad(&x, &g, b, h, w, cin, kh, kw, cout, b, &p);
        for bp in [1, 2] {
            let gw = conv_wgrad(&x, &g, b, h, w, cin, kh, kw, cout, bp, &p);
            assert_eq!(gw, full, "b_p={bp}");
        }
    }

    #[test]
    fn pool_and_bwd_route_max() {
        // One image, 2x2 -> 1x1, single channel.
        let x = [1.0f32, 3.0, 2.0, 0.5];
        let y = maxpool2x2(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![3.0]);
        let g = maxpool2x2_bwd(&x, &y, &[2.0], 1, 2, 2, 1);
        assert_eq!(g, vec![0.0, 2.0, 0.0, 0.0]);
        // Ties: every tied position receives the gradient.
        let xt = [7.0f32, 7.0, 1.0, 0.0];
        let yt = maxpool2x2(&xt, 1, 2, 2, 1);
        let gt = maxpool2x2_bwd(&xt, &yt, &[1.0], 1, 2, 2, 1);
        assert_eq!(gt, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn xent_uniform_and_confident() {
        let (loss, acc, grad) = softmax_xent(&[0.0; 8], &[0, 1], 2, 4);
        assert!((loss - (4f32).ln()).abs() < 1e-5);
        assert!((acc - 0.5).abs() < 1e-6); // first-occurrence argmax = 0
        // Uniform softmax grad: (1/n - onehot)/b.
        assert!((grad[0] - (0.25 - 1.0) / 2.0).abs() < 1e-6);
        assert!((grad[1] - 0.25 / 2.0).abs() < 1e-6);
        let (loss2, acc2, _) = softmax_xent(&[10.0, 0.0, 0.0], &[0], 1, 3);
        assert!(loss2 < 1e-3);
        assert_eq!(acc2, 1.0);
    }

    #[test]
    fn flip_w_rotates_and_swaps() {
        // k=1: flip is a pure [cin,cout] -> [cout,cin] transpose.
        let wt = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [1,1,2,3]
        let f = flip_w(&wt, 1, 1, 2, 3);
        assert_eq!(f, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]); // [1,1,3,2]
    }

    #[test]
    fn normalize_bp_rules() {
        assert_eq!(normalize_bp(32, 0), 32);
        assert_eq!(normalize_bp(32, 99), 32);
        assert_eq!(normalize_bp(32, 8), 8);
        assert_eq!(normalize_bp(32, 7), 4); // largest divisor <= 7
        assert_eq!(normalize_bp(1, 1), 1);
    }
}
