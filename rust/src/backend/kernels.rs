//! Native CPU kernels for the four hot ops (paper §III) — packed
//! microkernel f32 GEMM, conv via im2col lowering with the `b_p`
//! batching knob, 2x2 max-pool, and fused softmax + cross-entropy —
//! pure functions over `&[f32]` slices so the [`super::NativeBackend`],
//! the benches, and the parity tests all drive exactly the same code.
//!
//! Ports of `python/compile/kernels/{gemm,conv_gemm,pool,softmax_xent}.py`
//! with the paper's CPU schedule instead of the Pallas/TPU one:
//!
//! * GEMM is a BLIS-style **packed** schedule: A row-panels and B
//!   column-panels are repacked into contiguous cache-blocked buffers
//!   ([`pack_a`]/[`pack_b`]) and consumed by an [`MR`]x[`NR`]
//!   register-tiled [`microkernel`] whose inner loop is fixed-size and
//!   bounds-check-free, so the autovectorizer emits wide f32 lanes.
//!   Cache-level block caps (MC/NC/KC) come from [`BlockPlan`], seeded
//!   by a one-shot calibration probe (see [`calibrated_caps`]).
//! * **Bitwise determinism**: every output element accumulates
//!   `a[i,kk]*b[kk,j]` in ascending-kk order with exactly one mul + one
//!   add per kk, no matter the packing, block sizes, pool size, or
//!   `b_p`. Between KC blocks the partial sum round-trips through C
//!   memory (an exact f32 store/load), so KC blocking cannot
//!   reassociate the chain. The unpacked PR 7 kernel is kept as
//!   [`gemm_unpacked_into`] and property tests assert the two paths are
//!   bitwise identical.
//! * Bias-add and ReLU **epilogues are fused** into the microkernel's
//!   final write-back ([`Epilogue`]) so `fc_forward`/`conv_phase` no
//!   longer make separate full-tensor passes; the fused value
//!   `relu(sum + bias[j])` is computed with the same two operations the
//!   separate passes used, keeping goldens bitwise stable.
//! * Parallelism runs on the persistent [`super::pool`] worker pool
//!   (deterministic static partition, no per-call thread spawns):
//!   GEMM over contiguous row panels of C, conv additionally over
//!   `b_p` chunks when there are enough of them to fill the pool.
//! * All sizable temporaries (packed panels, im2col D-hat, accumulator
//!   tiles) come from the per-thread [`super::scratch`] arena: zero
//!   steady-state heap allocations.
//! * Conv lowers all `b_p` images into one D-hat and runs ONE large GEMM
//!   per chunk (paper Fig 2): `b_p = b` is the CPU strategy (max tile
//!   utilization, b x the lowering memory), `b_p = 1` the GPU/Caffe
//!   strategy (Fig 4's tradeoff).

use super::pool::{self, WorkerPool};
use super::scratch;

/// Microkernel register-tile rows: each inner-loop step updates an
/// MR x NR accumulator tile held in registers (6x16 f32 = 12 YMM
/// accumulators on AVX2, the classic f32 shape).
pub const MR: usize = 6;
/// Microkernel register-tile columns (one cache line of f32).
pub const NR: usize = 16;

/// Round `x` up to a multiple of `m`.
fn ceil_to(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Largest 8-aligned tile <= `max_tile` that splits `n` evenly-ish.
///
/// Naive `min(max_tile, n)` pads the last tile: K=800 with max 512 ->
/// tiles of 512 + 288 (21.9% wasted MACs against a 512 accumulator).
/// Splitting into ceil(n/max_tile) near-equal tiles (800 -> 2x400)
/// eliminates the waste. Must match python/compile/kernels/gemm.py.
/// (The packed path uses [`pick_block`], the same split with the
/// microkernel's own alignment.)
pub fn pick_tile(n: usize, max_tile: usize) -> usize {
    pick_block(n, max_tile, 8)
}

/// Near-equal split of `n` into blocks of at most ~`max_block`, rounded
/// up to a multiple of `align`. The generalization of [`pick_tile`]
/// the packed kernel needs: MC must align to [`MR`], NC to [`NR`], KC
/// to nothing (align = 1).
pub fn pick_block(n: usize, max_block: usize, align: usize) -> usize {
    let n = n.max(1);
    if n <= max_block {
        return ceil_to(n, align);
    }
    let n_blocks = n.div_ceil(max_block);
    ceil_to(n.div_ceil(n_blocks), align)
}

/// Blocked-GEMM schedule knobs: caps for the cache-level block sizes
/// (`bm` -> MC, `bn` -> NC, `bk` -> KC — [`BlockPlan::from_params`]
/// derives the actual near-equal splits) plus the row-panel thread
/// count. `Default` seeds the caps from the one-shot calibration probe.
/// Results are **bitwise invariant** to every field.
#[derive(Clone, Copy, Debug)]
pub struct GemmParams {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
    pub threads: usize,
}

impl Default for GemmParams {
    fn default() -> Self {
        let (mc, nc, kc) = calibrated_caps();
        Self { bm: mc, bn: nc, bk: kc, threads: default_threads() }
    }
}

impl GemmParams {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads: threads.max(1), ..Self::default() }
    }
}

/// Worker threads for kernel row panels: `OMNIVORE_THREADS` if set, else
/// the host's available parallelism, capped at 16. (The persistent pool
/// in [`super::pool`] is sized from this unless `--backend-threads`
/// overrides it first.)
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("OMNIVORE_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 16)
}

/// Fallback cache-block caps (MC, NC, KC) when the probe is skipped.
const DEFAULT_CAPS: (usize, usize, usize) = (120, 512, 288);

/// Cache-block caps (MC, NC, KC) for default-constructed [`GemmParams`].
///
/// Derived once per process: `OMNIVORE_MC`/`OMNIVORE_NC`/`OMNIVORE_KC`
/// env overrides win; otherwise a small single-thread timing probe runs
/// the packed schedule at a few candidate (MC, KC) pairs on a synthetic
/// GEMM shaped like the paper's conv lowering and keeps the fastest.
/// The probe picks *throughput only* — block sizes never change values
/// (see the module docs), so timing noise cannot break determinism.
pub fn calibrated_caps() -> (usize, usize, usize) {
    use std::sync::OnceLock;
    static CAPS: OnceLock<(usize, usize, usize)> = OnceLock::new();
    *CAPS.get_or_init(|| {
        let env = |key: &str| {
            std::env::var(key).ok().and_then(|v| v.trim().parse::<usize>().ok())
        };
        let (emc, enc, ekc) = (env("OMNIVORE_MC"), env("OMNIVORE_NC"), env("OMNIVORE_KC"));
        if let (Some(mc), Some(nc), Some(kc)) = (emc, enc, ekc) {
            return (mc.max(MR), nc.max(NR), kc.max(1));
        }
        // ~10 MFLOP per timing: cheap enough to pay once per process,
        // big enough that the L1/L2 working-set differences show.
        let (m, k, n) = (96, 384, 64);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 31) as f32 * 0.25 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 17) as f32 * 0.5 - 4.0).collect();
        let mut c = vec![0f32; m * n];
        let (dmc, dnc, dkc) = DEFAULT_CAPS;
        let mut best = (dmc, dkc);
        let mut best_t = f64::INFINITY;
        for (mc, kc) in [(60, 144), (120, 288), (120, 576), (240, 288)] {
            let p = GemmParams { bm: mc, bn: dnc, bk: kc, threads: 1 };
            gemm_fused_on(None, &mut c, &a, &b, m, k, n, &p, Epilogue::None); // warm
            let t0 = std::time::Instant::now();
            for _ in 0..2 {
                gemm_fused_on(None, &mut c, &a, &b, m, k, n, &p, Epilogue::None);
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt < best_t {
                best_t = dt;
                best = (mc, kc);
            }
        }
        (
            emc.unwrap_or(best.0).max(MR),
            enc.unwrap_or(dnc).max(NR),
            ekc.unwrap_or(best.1).max(1),
        )
    })
}

/// Cache-level block sizes actually used for one (rows, k, n) problem:
/// near-equal splits of each dimension under the [`GemmParams`] caps,
/// MC aligned to [`MR`] and NC to [`NR`] so edge handling stays in the
/// last strip only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockPlan {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl BlockPlan {
    pub fn from_params(rows: usize, k: usize, n: usize, p: &GemmParams) -> Self {
        Self {
            mc: pick_block(rows, p.bm.max(MR), MR),
            kc: pick_block(k, p.bk.max(1), 1),
            nc: pick_block(n, p.bn.max(NR), NR),
        }
    }
}

/// Write-back transform fused into the microkernel's final k-block
/// store (one pass over C instead of separate full-tensor passes).
/// Each variant applies the same per-element operations the separate
/// kernels applied, in the same order, so fusion is bitwise neutral.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Plain store.
    None,
    /// `c = max(c, 0)` (written as the `< 0` test so `-0.0` survives
    /// exactly like [`relu_inplace`]).
    Relu,
    /// `c += bias[j]` broadcast over rows.
    Bias(&'a [f32]),
    /// `c = relu(c + bias[j])`.
    BiasRelu(&'a [f32]),
}

/// Run `f` over `rows` split into at most `threads` contiguous row
/// panels of `c` (row width `cols`) on the persistent worker pool (or
/// `on`, when given). Each panel is a disjoint `&mut` slice; panel
/// boundaries never change any output element's accumulation order.
/// Inside a pool lane the split collapses to one panel (nested jobs run
/// inline anyway, and one panel packs B once instead of per panel).
fn par_row_panels<F>(
    on: Option<&WorkerPool>,
    c: &mut [f32],
    rows: usize,
    cols: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(c.len(), rows * cols);
    // At least 2*MR rows per panel: a panel smaller than two microtile
    // rows repacks B for almost no work.
    let t = if pool::in_pool() {
        1
    } else {
        threads.max(1).min(rows.div_ceil(2 * MR)).max(1)
    };
    if t <= 1 {
        f(0, rows, c);
        return;
    }
    let base = rows / t;
    let extra = rows % t;
    let cbase = c.as_mut_ptr() as usize;
    let run = |p: usize| {
        let row0 = p * base + p.min(extra);
        let take = base + usize::from(p < extra);
        // SAFETY: panel p covers rows [row0, row0 + take), disjoint
        // across p, and the pool runs each chunk index exactly once, so
        // no two lanes alias any element of `c`.
        let panel = unsafe {
            std::slice::from_raw_parts_mut((cbase as *mut f32).add(row0 * cols), take * cols)
        };
        f(row0, take, panel);
    };
    match on {
        Some(p) => p.run(t, run),
        None => pool::global().run(t, run),
    }
}

/// Split `buf` into `nchunks` equal disjoint chunks and run `f` on each
/// across the pool (chunk `ci` -> lane `ci % lanes`, deterministic).
fn par_chunks<F>(on: &WorkerPool, buf: &mut [f32], chunk: usize, nchunks: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(buf.len(), chunk * nchunks);
    let base = buf.as_mut_ptr() as usize;
    on.run(nchunks, |ci| {
        // SAFETY: chunk ci owns the disjoint range [ci*chunk, (ci+1)*chunk)
        // of `buf`, and the pool runs every chunk index exactly once, so
        // no two lanes alias.
        let s = unsafe {
            std::slice::from_raw_parts_mut((base as *mut f32).add(ci * chunk), chunk)
        };
        f(ci, s);
    });
}

/// Pack an [mc x kc] block of A (row-major, leading dimension `lda`)
/// into [`MR`]-row strips, k-major within each strip:
/// `apack[(s*kc + kk)*MR + r] = A[row0 + s*MR + r, k0 + kk]`.
/// Rows past `mc` in the last strip are zero-filled; they only feed
/// accumulator rows the write-back never stores.
fn pack_a(apack: &mut [f32], a: &[f32], lda: usize, row0: usize, mc: usize, k0: usize, kc: usize) {
    for s in 0..mc.div_ceil(MR) {
        let rows = MR.min(mc - s * MR);
        let dst = &mut apack[s * kc * MR..][..kc * MR];
        for r in 0..MR {
            if r < rows {
                let arow = &a[(row0 + s * MR + r) * lda + k0..][..kc];
                for (kk, &v) in arow.iter().enumerate() {
                    dst[kk * MR + r] = v;
                }
            } else {
                for kk in 0..kc {
                    dst[kk * MR + r] = 0.0;
                }
            }
        }
    }
}

/// Pack a [kc x nc] block of B (row-major, leading dimension `ldb`)
/// into [`NR`]-column strips, k-major within each strip:
/// `bpack[(s*kc + kk)*NR + j] = B[k0 + kk, j0 + s*NR + j]`.
/// Columns past `nc` in the last strip are zero-filled; they only feed
/// accumulator columns the write-back never stores.
fn pack_b(bpack: &mut [f32], b: &[f32], ldb: usize, k0: usize, kc: usize, j0: usize, nc: usize) {
    for s in 0..nc.div_ceil(NR) {
        let cols = NR.min(nc - s * NR);
        let dst = &mut bpack[s * kc * NR..][..kc * NR];
        for kk in 0..kc {
            let src = &b[(k0 + kk) * ldb + j0 + s * NR..];
            let out = &mut dst[kk * NR..][..NR];
            if cols == NR {
                out.copy_from_slice(&src[..NR]);
            } else {
                out[..cols].copy_from_slice(&src[..cols]);
                out[cols..].iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }
}

/// The MRxNR register-tiled inner kernel over one packed A strip and one
/// packed B strip: `kc` steps of `acc[r][j] += a[r] * b[j]`.
///
/// Determinism: the accumulator tile LOADS the partial sums already in C
/// when `first` is false (f32 memory round-trips are exact), adds one
/// mul + one add per kk in ascending-kk order, and stores back — so KC
/// blocking never reassociates any element's accumulation chain, and the
/// result is bitwise identical to the single-pass unpacked kernel. The
/// epilogue is applied only on the final k block (`last`), using the
/// same per-element operations as the standalone bias/ReLU kernels.
#[allow(clippy::too_many_arguments)]
#[inline]
fn microkernel(
    c: &mut [f32],
    ldc: usize,
    ap: &[f32],
    bp: &[f32],
    mr: usize,
    nr: usize,
    first: bool,
    last: bool,
    epi: Epilogue<'_>,
    jabs: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    if !first {
        for (r, accr) in acc.iter_mut().take(mr).enumerate() {
            accr[..nr].copy_from_slice(&c[r * ldc..][..nr]);
        }
    }
    // Hot loop: fixed-size MRxNR updates with no bounds checks (the
    // `try_into` array casts are compile-time-known from chunks_exact),
    // which LLVM turns into wide f32 FMA-shaped mul+add lanes.
    for (ak, bk) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        let ak: &[f32; MR] = ak.try_into().unwrap();
        let bk: &[f32; NR] = bk.try_into().unwrap();
        for (accr, &av) in acc.iter_mut().zip(ak) {
            for (cv, &bv) in accr.iter_mut().zip(bk) {
                *cv += av * bv;
            }
        }
    }
    if !last {
        for (r, accr) in acc.iter().take(mr).enumerate() {
            c[r * ldc..][..nr].copy_from_slice(&accr[..nr]);
        }
        return;
    }
    match epi {
        Epilogue::None => {
            for (r, accr) in acc.iter().take(mr).enumerate() {
                c[r * ldc..][..nr].copy_from_slice(&accr[..nr]);
            }
        }
        Epilogue::Relu => {
            for (r, accr) in acc.iter().take(mr).enumerate() {
                for (cv, &v) in c[r * ldc..][..nr].iter_mut().zip(accr.iter()) {
                    *cv = if v < 0.0 { 0.0 } else { v };
                }
            }
        }
        Epilogue::Bias(bias) => {
            let bs = &bias[jabs..][..nr];
            for (r, accr) in acc.iter().take(mr).enumerate() {
                let crow = &mut c[r * ldc..][..nr];
                for ((cv, &v), &bv) in crow.iter_mut().zip(accr.iter()).zip(bs) {
                    *cv = v + bv;
                }
            }
        }
        Epilogue::BiasRelu(bias) => {
            let bs = &bias[jabs..][..nr];
            for (r, accr) in acc.iter().take(mr).enumerate() {
                let crow = &mut c[r * ldc..][..nr];
                for ((cv, &v), &bv) in crow.iter_mut().zip(accr.iter()).zip(bs) {
                    let x = v + bv;
                    *cv = if x < 0.0 { 0.0 } else { x };
                }
            }
        }
    }
}

/// The packed BLIS loop nest (jc/NC -> pc/KC -> pack B -> ic/MC ->
/// pack A -> NR strip -> MR strip -> microkernel) over one contiguous
/// row panel of C. `arow0` is the panel's first row in A.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_panel(
    panel: &mut [f32],
    a: &[f32],
    b: &[f32],
    arow0: usize,
    prows: usize,
    k: usize,
    n: usize,
    plan: BlockPlan,
    epi: Epilogue<'_>,
) {
    let mut apack = scratch::take(plan.mc * plan.kc);
    let mut bpack = scratch::take(plan.nc * plan.kc);
    let mut jc = 0;
    while jc < n {
        let nc = plan.nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = plan.kc.min(k - pc);
            let first = pc == 0;
            let last = pc + kc == k;
            pack_b(&mut bpack[..nc.div_ceil(NR) * NR * kc], b, n, pc, kc, jc, nc);
            let mut ic = 0;
            while ic < prows {
                let mc = plan.mc.min(prows - ic);
                pack_a(&mut apack[..mc.div_ceil(MR) * MR * kc], a, k, arow0 + ic, mc, pc, kc);
                let mut jr = 0;
                while jr < nc {
                    let nr = NR.min(nc - jr);
                    let bp = &bpack[jr * kc..][..NR * kc];
                    let mut ir = 0;
                    while ir < mc {
                        let mr = MR.min(mc - ir);
                        let ap = &apack[ir * kc..][..MR * kc];
                        let coff = (ic + ir) * n + jc + jr;
                        microkernel(
                            &mut panel[coff..],
                            n,
                            ap,
                            bp,
                            mr,
                            nr,
                            first,
                            last,
                            epi,
                            jc + jr,
                        );
                        ir += MR;
                    }
                    jr += NR;
                }
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// [`gemm_fused_into`] with an explicit pool (None = run panels on the
/// process-global pool). The seam the pool-size property tests use.
#[allow(clippy::too_many_arguments)]
pub fn gemm_fused_on(
    on: Option<&WorkerPool>,
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p: &GemmParams,
    epi: Epilogue<'_>,
) {
    assert_eq!(a.len(), m * k, "gemm: A shape");
    assert_eq!(b.len(), k * n, "gemm: B shape");
    assert_eq!(c.len(), m * n, "gemm: C shape");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // No k terms: the sum is 0, then the epilogue.
        c.iter_mut().for_each(|v| *v = 0.0);
        match epi {
            Epilogue::None | Epilogue::Relu => {}
            Epilogue::Bias(bias) => bias_add(c, bias, m, n),
            Epilogue::BiasRelu(bias) => {
                bias_add(c, bias, m, n);
                relu_inplace(c);
            }
        }
        return;
    }
    // Tiny problems: panel/packing overhead beats any parallel win.
    let threads = if 2 * m * k * n < (1 << 16) { 1 } else { p.threads };
    par_row_panels(on, c, m, n, threads, |row0, nrows, panel| {
        let plan = BlockPlan::from_params(nrows, k, n, p);
        gemm_packed_panel(panel, a, b, row0, nrows, k, n, plan, epi);
    });
}

/// C = A @ B with a fused write-back epilogue: a [m,k] row-major,
/// b [k,n] row-major, c [m,n]. See the module docs for the determinism
/// argument; results are bitwise invariant to block sizes, pool size,
/// thread count, and packing.
#[allow(clippy::too_many_arguments)]
pub fn gemm_fused_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p: &GemmParams,
    epi: Epilogue<'_>,
) {
    gemm_fused_on(None, c, a, b, m, k, n, p, epi);
}

/// C = A @ B into `c` (no epilogue): the packed microkernel schedule.
pub fn gemm_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p: &GemmParams,
) {
    gemm_fused_on(None, c, a, b, m, k, n, p, Epilogue::None);
}

/// Allocating wrapper over [`gemm_into`].
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, p: &GemmParams) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_into(&mut c, a, b, m, k, n, p);
    c
}

/// Allocating GEMM on an explicit pool (pool-size property tests).
pub fn gemm_with_pool(
    on: &WorkerPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p: &GemmParams,
) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_fused_on(Some(on), &mut c, a, b, m, k, n, p, Epilogue::None);
    c
}

/// The PR 7 unpacked C-tile-stationary reference kernel, kept verbatim
/// (modulo the pool and the arena) as the bitwise oracle for the packed
/// path and as the bench baseline the packed speedup is measured
/// against. Every `c[i,j]` accumulates in ascending-kk order, exactly
/// like the packed kernel.
pub fn gemm_unpacked_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p: &GemmParams,
) {
    assert_eq!(a.len(), m * k, "gemm: A shape");
    assert_eq!(b.len(), k * n, "gemm: B shape");
    assert_eq!(c.len(), m * n, "gemm: C shape");
    if m == 0 || n == 0 {
        return;
    }
    let threads = if 2 * m * k * n < (1 << 16) { 1 } else { p.threads };
    let tn = pick_tile(n, p.bn).min(n.max(1));
    let tk = pick_tile(k.max(1), p.bk);
    par_row_panels(None, c, m, n, threads, |row0, nrows, panel| {
        let tm = pick_tile(nrows, p.bm);
        let mut acc = scratch::take(tm * tn);
        let mut i0 = 0;
        while i0 < nrows {
            let il = tm.min(nrows - i0);
            let mut j0 = 0;
            while j0 < n {
                let jl = tn.min(n - j0);
                acc[..il * jl].iter_mut().for_each(|v| *v = 0.0);
                let mut k0 = 0;
                while k0 < k {
                    let kl = tk.min(k - k0);
                    for ii in 0..il {
                        let arow = &a[(row0 + i0 + ii) * k + k0..][..kl];
                        let crow = &mut acc[ii * jl..][..jl];
                        for (kk, &av) in arow.iter().enumerate() {
                            let brow = &b[(k0 + kk) * n + j0..][..jl];
                            for (cv, &bv) in crow.iter_mut().zip(brow) {
                                *cv += av * bv;
                            }
                        }
                    }
                    k0 += kl;
                }
                for ii in 0..il {
                    panel[(i0 + ii) * n + j0..][..jl].copy_from_slice(&acc[ii * jl..][..jl]);
                }
                j0 += jl;
            }
            i0 += il;
        }
    });
}

/// Allocating wrapper over [`gemm_unpacked_into`].
pub fn gemm_unpacked(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    p: &GemmParams,
) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_unpacked_into(&mut c, a, b, m, k, n, p);
    c
}

/// C += A^T @ B: a [p_rows, m], b [p_rows, n], c [m, n] accumulated IN
/// PLACE in ascending-p order (weight gradients: D-hat^T @ g-hat). The
/// in-place, p-ascending accumulation makes chunked callers (conv wgrad
/// over `b_p` chunks) bitwise independent of the chunking.
pub fn gemm_tn_acc(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    p_rows: usize,
    m: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), p_rows * m, "gemm_tn: A shape");
    assert_eq!(b.len(), p_rows * n, "gemm_tn: B shape");
    assert_eq!(c.len(), m * n, "gemm_tn: C shape");
    let threads = if 2 * p_rows * m * n < (1 << 16) { 1 } else { threads };
    par_row_panels(None, c, m, n, threads, |row0, nrows, panel| {
        for pp in 0..p_rows {
            let brow = &b[pp * n..][..n];
            for ii in 0..nrows {
                let av = a[pp * m + row0 + ii];
                if av != 0.0 {
                    let crow = &mut panel[ii * n..][..n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    });
}

/// C = A @ B^T into `c`: a [m,k], b [n,k] (activation gradients:
/// `g @ W^T` without materializing the transpose). Row-wise dot products
/// accumulate in ascending-k order.
pub fn gemm_nt_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_nt: A shape");
    assert_eq!(b.len(), n * k, "gemm_nt: B shape");
    assert_eq!(c.len(), m * n, "gemm_nt: C shape");
    let threads = if 2 * m * k * n < (1 << 16) { 1 } else { threads };
    par_row_panels(None, c, m, n, threads, |row0, nrows, panel| {
        for ii in 0..nrows {
            let arow = &a[(row0 + ii) * k..][..k];
            let crow = &mut panel[ii * n..][..n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..][..k];
                let mut s = 0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    s += av * bv;
                }
                *cv = s;
            }
        }
    });
}

/// Allocating wrapper over [`gemm_nt_into`].
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_nt_into(&mut c, a, b, m, k, n, threads);
    c
}

/// Lowering step (paper Fig 2): write D-hat rows for `b` NHWC images
/// into `dhat` ([b*h*w, kh*kw*cin], (kh, kw, cin) row-major — matching
/// `im2col_ref` / the HWIO weight reshape). SAME padding, stride 1, odd
/// kernels. Every element of `dhat` is written (padding zones zeroed).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    dhat: &mut [f32],
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
) {
    let kkc = kh * kw * cin;
    assert_eq!(dhat.len(), b * h * w * kkc, "im2col: D-hat shape");
    assert_eq!(x.len(), b * h * w * cin, "im2col: x shape");
    let (ph, pw) = (kh / 2, kw / 2);
    for img in 0..b {
        let xi = &x[img * h * w * cin..][..h * w * cin];
        for y in 0..h {
            for xw in 0..w {
                let drow = &mut dhat[((img * h + y) * w + xw) * kkc..][..kkc];
                for ki in 0..kh {
                    let iy = (y + ki).wrapping_sub(ph);
                    for kj in 0..kw {
                        let ix = (xw + kj).wrapping_sub(pw);
                        let dst = &mut drow[(ki * kw + kj) * cin..][..cin];
                        if iy < h && ix < w {
                            dst.copy_from_slice(&xi[(iy * w + ix) * cin..][..cin]);
                        } else {
                            dst.iter_mut().for_each(|v| *v = 0.0);
                        }
                    }
                }
            }
        }
    }
}

/// Normalize the `b_p` knob: 0 (or > b) means the paper's CPU pick
/// `b_p = b`; a non-divisor falls back to the largest divisor of `b`
/// below it (the python kernel asserts instead; the runtime must not).
pub fn normalize_bp(b: usize, b_p: usize) -> usize {
    if b_p == 0 || b_p >= b {
        return b.max(1);
    }
    let mut bp = b_p;
    while b % bp != 0 {
        bp -= 1;
    }
    bp
}

/// SAME stride-1 conv via lowering + batched GEMM (paper §III, Fig 2)
/// with an optional fused bias(+ReLU) epilogue, writing into `out`.
/// x [b,h,w,cin], w [kh,kw,cin,cout] (HWIO) -> out [b,h,w,cout].
///
/// `b_p` images are lowered per chunk into one D-hat feeding ONE GEMM of
/// `b_p*h*w` rows; the result is bitwise b_p-invariant (each output row
/// belongs to exactly one image) — only the schedule and the D-hat
/// footprint (`4*b_p*h*w*kh*kw*cin` bytes) change. When the chunk count
/// can fill the pool, chunks run in parallel lanes (im2col AND GEMM),
/// each lane's inner GEMM inline; otherwise chunks run sequentially
/// with row-parallel GEMMs. Both schedules are bitwise identical.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fused_into(
    out: &mut [f32],
    x: &[f32],
    wt: &[f32],
    bias: Option<&[f32]>,
    relu: bool,
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    b_p: usize,
    p: &GemmParams,
) {
    assert_eq!(x.len(), b * h * w * cin, "conv: x shape");
    assert_eq!(wt.len(), kh * kw * cin * cout, "conv: w shape");
    assert_eq!(out.len(), b * h * w * cout, "conv: out shape");
    let b_p = normalize_bp(b, b_p);
    let kkc = kh * kw * cin;
    let rows = b_p * h * w;
    let nchunks = b / b_p;
    let epi = match (bias, relu) {
        (Some(bv), true) => Epilogue::BiasRelu(bv),
        (Some(bv), false) => Epilogue::Bias(bv),
        (None, true) => Epilogue::Relu,
        (None, false) => Epilogue::None,
    };
    let in_chunk = b_p * h * w * cin;
    let out_chunk = b_p * h * w * cout;
    let work = |ci: usize, out_c: &mut [f32]| {
        let mut dhat = scratch::take(rows * kkc);
        im2col_into(&mut dhat, &x[ci * in_chunk..][..in_chunk], b_p, h, w, cin, kh, kw);
        gemm_fused_into(out_c, &dhat, wt, rows, kkc, cout, p, epi);
    };
    if nchunks > 1 && p.threads > 1 && !pool::in_pool() {
        let pl = pool::global();
        if nchunks >= pl.lanes() && pl.lanes() > 1 {
            par_chunks(pl, out, out_chunk, nchunks, work);
            return;
        }
    }
    for ci in 0..nchunks {
        work(ci, &mut out[ci * out_chunk..][..out_chunk]);
    }
}

/// Allocating SAME conv, no epilogue (bench/test surface; the backend
/// uses [`conv2d_fused_into`]).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same(
    x: &[f32],
    wt: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    b_p: usize,
    p: &GemmParams,
) -> Vec<f32> {
    let mut out = vec![0f32; b * h * w * cout];
    conv2d_fused_into(&mut out, x, wt, None, false, b, h, w, cin, kh, kw, cout, b_p, p);
    out
}

/// dL/dw for SAME stride-1 conv as chunked `D-hat^T @ g-hat` GEMMs
/// (the paper's lowering applied to the backward pass), into `gw`
/// ([kh,kw,cin,cout] flat). x [b,h,w,cin], g [b,h,w,cout]. Chunks stay
/// SEQUENTIAL: the in-place p-ascending accumulation that makes the
/// result bitwise b_p-invariant also orders chunk contributions, so
/// parallelizing across chunks here would reassociate the sums. The
/// row panels of each chunk's GEMM parallelize instead.
#[allow(clippy::too_many_arguments)]
pub fn conv_wgrad_into(
    gw: &mut [f32],
    x: &[f32],
    g: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    b_p: usize,
    p: &GemmParams,
) {
    assert_eq!(x.len(), b * h * w * cin, "wgrad: x shape");
    assert_eq!(g.len(), b * h * w * cout, "wgrad: g shape");
    let b_p = normalize_bp(b, b_p);
    let kkc = kh * kw * cin;
    let rows = b_p * h * w;
    assert_eq!(gw.len(), kkc * cout, "wgrad: gw shape");
    gw.iter_mut().for_each(|v| *v = 0.0);
    let mut dhat = scratch::take(rows * kkc);
    let mut c0 = 0;
    while c0 < b {
        im2col_into(&mut dhat, &x[c0 * h * w * cin..][..rows * cin], b_p, h, w, cin, kh, kw);
        let ghat = &g[c0 * h * w * cout..][..rows * cout];
        gemm_tn_acc(gw, &dhat, ghat, rows, kkc, cout, p.threads);
        c0 += b_p;
    }
}

/// Allocating wrapper over [`conv_wgrad_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv_wgrad(
    x: &[f32],
    g: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    b_p: usize,
    p: &GemmParams,
) -> Vec<f32> {
    let mut gw = vec![0f32; kh * kw * cin * cout];
    conv_wgrad_into(&mut gw, x, g, b, h, w, cin, kh, kw, cout, b_p, p);
    gw
}

/// HWIO kernel -> 180-degree-rotated, in/out-swapped kernel for the
/// input-gradient conv (`_flip_w` in python model.py), into `out`
/// ([kh,kw,cout,cin] flat): out[i,j,o,c] = w[kh-1-i, kw-1-j, c, o].
pub fn flip_w_into(out: &mut [f32], wt: &[f32], kh: usize, kw: usize, cin: usize, cout: usize) {
    assert_eq!(wt.len(), kh * kw * cin * cout, "flip_w: shape");
    assert_eq!(out.len(), kh * kw * cout * cin, "flip_w: out shape");
    for i in 0..kh {
        for j in 0..kw {
            for c in 0..cin {
                for o in 0..cout {
                    out[((i * kw + j) * cout + o) * cin + c] =
                        wt[(((kh - 1 - i) * kw + (kw - 1 - j)) * cin + c) * cout + o];
                }
            }
        }
    }
}

/// Allocating wrapper over [`flip_w_into`].
pub fn flip_w(wt: &[f32], kh: usize, kw: usize, cin: usize, cout: usize) -> Vec<f32> {
    let mut out = vec![0f32; kh * kw * cout * cin];
    flip_w_into(&mut out, wt, kh, kw, cin, cout);
    out
}

/// 2x2 stride-2 max pool into `out`. x [b,h,w,c] (h, w even) ->
/// out [b,h/2,w/2,c].
pub fn maxpool2x2_into(out: &mut [f32], x: &[f32], b: usize, h: usize, w: usize, c: usize) {
    assert_eq!(x.len(), b * h * w * c, "pool: x shape");
    assert!(h % 2 == 0 && w % 2 == 0, "pool: odd spatial dims");
    let (h2, w2) = (h / 2, w / 2);
    assert_eq!(out.len(), b * h2 * w2 * c, "pool: out shape");
    for img in 0..b {
        for y in 0..h2 {
            for xw in 0..w2 {
                let orow = &mut out[((img * h2 + y) * w2 + xw) * c..][..c];
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let irow = &x[((img * h + 2 * y + dy) * w + 2 * xw + dx) * c..][..c];
                    if dy == 0 && dx == 0 {
                        orow.copy_from_slice(irow);
                    } else {
                        for (o, &v) in orow.iter_mut().zip(irow) {
                            if v > *o {
                                *o = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Allocating wrapper over [`maxpool2x2_into`].
pub fn maxpool2x2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0f32; b * (h / 2) * (w / 2) * c];
    maxpool2x2_into(&mut out, x, b, h, w, c);
    out
}

/// Max-pool backward into `out`: route pooled grads to max positions;
/// ties (exact float equality) receive the gradient in every tied
/// position — the `gu * (x == yu)` rule of python model.py
/// `_maxpool_bwd`. Every element of `out` is written.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2x2_bwd_into(
    out: &mut [f32],
    x: &[f32],
    y: &[f32],
    g: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) {
    let (h2, w2) = (h / 2, w / 2);
    assert_eq!(x.len(), b * h * w * c, "pool_bwd: x shape");
    assert_eq!(y.len(), b * h2 * w2 * c, "pool_bwd: y shape");
    assert_eq!(g.len(), y.len(), "pool_bwd: g shape");
    assert_eq!(out.len(), x.len(), "pool_bwd: out shape");
    out.iter_mut().for_each(|v| *v = 0.0);
    for img in 0..b {
        for yy in 0..h2 {
            for xw in 0..w2 {
                let base = ((img * h2 + yy) * w2 + xw) * c;
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let idx = ((img * h + 2 * yy + dy) * w + 2 * xw + dx) * c;
                    for cc in 0..c {
                        if x[idx + cc] == y[base + cc] {
                            out[idx + cc] = g[base + cc];
                        }
                    }
                }
            }
        }
    }
}

/// Allocating wrapper over [`maxpool2x2_bwd_into`].
pub fn maxpool2x2_bwd(
    x: &[f32],
    y: &[f32],
    g: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    maxpool2x2_bwd_into(&mut out, x, y, g, b, h, w, c);
    out
}

/// Fused softmax + cross-entropy into `grad`: logits [b,n], labels [b]
/// -> (mean loss, accuracy); `grad` [b,n] already divided by b. Matches
/// `softmax_xent_ref`: max-subtracted logsumexp, first-occurrence argmax.
pub fn softmax_xent_into(
    grad: &mut [f32],
    logits: &[f32],
    labels: &[i32],
    b: usize,
    n: usize,
) -> (f32, f32) {
    assert_eq!(logits.len(), b * n, "xent: logits shape");
    assert_eq!(labels.len(), b, "xent: labels shape");
    assert_eq!(grad.len(), b * n, "xent: grad shape");
    let mut loss = 0f64;
    let mut correct = 0usize;
    for i in 0..b {
        let row = &logits[i * n..][..n];
        let mut zmax = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &z) in row.iter().enumerate() {
            if z > zmax {
                zmax = z;
                argmax = j;
            }
        }
        let mut sum = 0f32;
        for &z in row {
            sum += (z - zmax).exp();
        }
        let lse = sum.ln();
        let y = labels[i] as usize;
        loss += (lse - (row[y] - zmax)) as f64;
        if argmax == y {
            correct += 1;
        }
        let grow = &mut grad[i * n..][..n];
        for (j, gz) in grow.iter_mut().enumerate() {
            let p = ((row[j] - zmax) - lse).exp();
            let onehot = if j == y { 1.0 } else { 0.0 };
            *gz = (p - onehot) / b as f32;
        }
    }
    ((loss / b as f64) as f32, correct as f32 / b as f32)
}

/// Allocating wrapper over [`softmax_xent_into`].
pub fn softmax_xent(logits: &[f32], labels: &[i32], b: usize, n: usize) -> (f32, f32, Vec<f32>) {
    let mut grad = vec![0f32; b * n];
    let (loss, acc) = softmax_xent_into(&mut grad, logits, labels, b, n);
    (loss, acc, grad)
}

/// y += bias broadcast over rows: y [rows, c], bias [c].
pub fn bias_add(y: &mut [f32], bias: &[f32], rows: usize, c: usize) {
    assert_eq!(y.len(), rows * c, "bias_add: y shape");
    assert_eq!(bias.len(), c, "bias_add: bias shape");
    for r in 0..rows {
        for (v, &bv) in y[r * c..][..c].iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

/// ReLU in place.
pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// g *= (z > 0): ReLU backward mask. Because `a = relu(z)` satisfies
/// `a <= 0.0 <=> z <= 0.0` bit-for-bit (positives survive unchanged,
/// everything else becomes 0.0), callers may pass the post-activation
/// tensor instead of the pre-activation one — which is what lets the
/// fused forward drop the pre-activation buffers entirely.
pub fn relu_bwd_inplace(g: &mut [f32], z: &[f32]) {
    assert_eq!(g.len(), z.len(), "relu_bwd: shape");
    for (gv, &zv) in g.iter_mut().zip(z) {
        if zv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Column sums: x [rows, c] -> [c] (bias gradients).
pub fn colsum(x: &[f32], rows: usize, c: usize) -> Vec<f32> {
    assert_eq!(x.len(), rows * c, "colsum: shape");
    let mut out = vec![0f32; c];
    for r in 0..rows {
        for (o, &v) in out.iter_mut().zip(&x[r * c..][..c]) {
            *o += v;
        }
    }
    out
}

/// D-hat footprint in bytes at a given `b_p` (paper Fig 4c memory curve).
pub fn lowered_bytes(b_p: usize, h: usize, w: usize, kh: usize, kw: usize, cin: usize) -> usize {
    4 * b_p * h * w * kh * kw * cin
}

/// FLOP count of a SAME conv as GFLOP (2 MACs per multiply-add).
#[allow(clippy::too_many_arguments)]
pub fn conv_gflops(
    b: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
) -> f64 {
    2.0 * (b * h * w) as f64 * cout as f64 * (kh * kw * cin) as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn gemm_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn pick_tile_near_equal_split() {
        // The documented 800/512 case: 2 tiles of 400, NOT 512 + 288.
        assert_eq!(pick_tile(800, 512), 400);
        // <= max: round up to 8.
        assert_eq!(pick_tile(10, 128), 16);
        assert_eq!(pick_tile(512, 512), 512);
        assert_eq!(pick_tile(128, 128), 128);
        // 1000 -> 2 tiles -> 500 -> 504 (8-aligned), covering in 504+496.
        assert_eq!(pick_tile(1000, 512), 504);
        assert_eq!(pick_tile(1, 128), 8);
    }

    #[test]
    fn block_plan_handles_ragged_shapes() {
        // The ISSUE's ragged trio: 800 rows, k=257, n=1.
        let p = GemmParams { bm: 128, bn: 512, bk: 256, threads: 1 };
        let plan = BlockPlan::from_params(800, 257, 1, &p);
        // 800 under a 128 cap -> 7 near-equal blocks of 115 -> MR-align.
        assert_eq!(plan.mc, 120);
        assert_eq!(plan.mc % MR, 0);
        // 257 under a 256 cap -> 2 near-equal blocks, no padding waste.
        assert_eq!(plan.kc, 129);
        // n=1 -> one NR-aligned block.
        assert_eq!(plan.nc, NR);
        // Coverage: the last block is never empty.
        for (dim, blk) in [(800, plan.mc), (257, plan.kc), (1, plan.nc)] {
            assert!((dim.div_ceil(blk) - 1) * blk < dim, "{dim}/{blk}");
        }
        // Degenerate caps clamp to the microtile.
        let degenerate = GemmParams { bm: 1, bn: 1, bk: 1, threads: 1 };
        let tiny = BlockPlan::from_params(4, 3, 2, &degenerate);
        assert_eq!((tiny.mc, tiny.kc, tiny.nc), (MR, 1, NR));
    }

    #[test]
    fn gemm_matches_naive_ragged() {
        // Ragged in every dimension (not multiples of any tile).
        let (m, k, n) = (13, 57, 9);
        let a = randv(m * k, 1);
        let b = randv(k * n, 2);
        let c = gemm(&a, &b, m, k, n, &GemmParams::with_threads(1));
        let want = gemm_naive(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_invariant_to_threads_and_tiles() {
        let (m, k, n) = (64, 800, 24);
        let a = randv(m * k, 3);
        let b = randv(k * n, 4);
        let base = gemm(&a, &b, m, k, n, &GemmParams { bm: 128, bn: 128, bk: 512, threads: 1 });
        for threads in [2, 4, 7] {
            for (bm, bn, bk) in [(128, 128, 512), (32, 16, 64), (8, 8, 8), (256, 256, 1024)] {
                let c = gemm(&a, &b, m, k, n, &GemmParams { bm, bn, bk, threads });
                assert_eq!(c, base, "threads={threads} tiles=({bm},{bn},{bk})");
            }
        }
    }

    #[test]
    fn packed_matches_unpacked_bitwise() {
        // The tentpole property: the packed microkernel schedule and the
        // PR 7 unpacked reference produce identical bits on ragged
        // shapes, across thread counts and block caps.
        let shapes = [
            ((13usize, 57usize, 9usize), 21u64),
            ((64, 800, 24), 22),
            ((100, 257, 1), 23),
            ((33, 1, 17), 24),
            ((5, 129, 40), 25),
        ];
        for (shape, seed) in shapes {
            let (m, k, n) = shape;
            let a = randv(m * k, seed);
            let b = randv(k * n, seed + 100);
            for threads in [1usize, 4] {
                for (bm, bn, bk) in [(128, 128, 512), (8, 8, 8), (48, 32, 129)] {
                    let p = GemmParams { bm, bn, bk, threads };
                    let packed = gemm(&a, &b, m, k, n, &p);
                    let unpacked = gemm_unpacked(&a, &b, m, k, n, &p);
                    assert_eq!(
                        packed, unpacked,
                        "shape={shape:?} threads={threads} caps=({bm},{bn},{bk})"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_epilogue_matches_separate_passes() {
        let (m, k, n) = (23, 41, 19);
        let a = randv(m * k, 31);
        let b = randv(k * n, 32);
        let bias = randv(n, 33);
        let p = GemmParams { bm: 16, bn: 32, bk: 16, threads: 2 };
        // Reference: unpacked GEMM then separate bias/relu passes.
        let mut want = gemm_unpacked(&a, &b, m, k, n, &p);
        bias_add(&mut want, &bias, m, n);
        let mut want_relu = want.clone();
        relu_inplace(&mut want_relu);
        // Fused bias.
        let mut got = vec![0f32; m * n];
        gemm_fused_into(&mut got, &a, &b, m, k, n, &p, Epilogue::Bias(&bias));
        assert_eq!(got, want, "fused bias");
        // Fused bias + relu.
        gemm_fused_into(&mut got, &a, &b, m, k, n, &p, Epilogue::BiasRelu(&bias));
        assert_eq!(got, want_relu, "fused bias+relu");
        // Fused relu only.
        let mut plain = gemm_unpacked(&a, &b, m, k, n, &p);
        relu_inplace(&mut plain);
        gemm_fused_into(&mut got, &a, &b, m, k, n, &p, Epilogue::Relu);
        assert_eq!(got, plain, "fused relu");
    }

    #[test]
    fn gemm_with_explicit_pools_is_bitwise_stable() {
        let (m, k, n) = (37, 65, 29);
        let a = randv(m * k, 41);
        let b = randv(k * n, 42);
        let p = GemmParams::with_threads(8);
        let base = gemm(&a, &b, m, k, n, &p);
        for lanes in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(lanes);
            let c = gemm_with_pool(&pool, &a, &b, m, k, n, &p);
            assert_eq!(c, base, "pool lanes={lanes}");
        }
    }

    #[test]
    fn zero_k_gemm_writes_zeros_and_epilogue() {
        let (m, n) = (3, 5);
        let bias: Vec<f32> = (0..n).map(|j| j as f32 - 2.0).collect();
        let mut c = vec![7f32; m * n];
        gemm_into(&mut c, &[], &[], m, 0, n, &GemmParams::with_threads(2));
        assert!(c.iter().all(|&v| v == 0.0));
        let mut c2 = vec![7f32; m * n];
        let p1 = GemmParams::with_threads(1);
        gemm_fused_into(&mut c2, &[], &[], m, 0, n, &p1, Epilogue::BiasRelu(&bias));
        for r in 0..m {
            for j in 0..n {
                let want = (bias[j]).max(0.0);
                assert_eq!(c2[r * n + j], want);
            }
        }
    }

    #[test]
    fn gemm_tn_and_nt_match_naive() {
        let (p, m, n) = (17, 11, 7);
        let a = randv(p * m, 5); // [p, m]
        let b = randv(p * n, 6); // [p, n]
        let mut c = vec![0f32; m * n];
        gemm_tn_acc(&mut c, &a, &b, p, m, n, 1);
        // naive A^T @ B
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f32;
                for pp in 0..p {
                    s += a[pp * m + i] * b[pp * n + j];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4);
            }
        }
        let (m2, k2, n2) = (9, 13, 5);
        let a2 = randv(m2 * k2, 7);
        let b2 = randv(n2 * k2, 8); // [n, k]
        let c2 = gemm_nt(&a2, &b2, m2, k2, n2, 1);
        for i in 0..m2 {
            for j in 0..n2 {
                let mut s = 0f32;
                for kk in 0..k2 {
                    s += a2[i * k2 + kk] * b2[j * k2 + kk];
                }
                assert!((c2[i * n2 + j] - s).abs() < 1e-4);
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn conv_naive(
        x: &[f32],
        wt: &[f32],
        b: usize,
        h: usize,
        w: usize,
        cin: usize,
        kh: usize,
        kw: usize,
        cout: usize,
    ) -> Vec<f32> {
        let (ph, pw) = (kh / 2, kw / 2);
        let mut out = vec![0f32; b * h * w * cout];
        for img in 0..b {
            for y in 0..h {
                for xw in 0..w {
                    for o in 0..cout {
                        let mut s = 0f32;
                        for ki in 0..kh {
                            for kj in 0..kw {
                                let iy = (y + ki).wrapping_sub(ph);
                                let ix = (xw + kj).wrapping_sub(pw);
                                if iy < h && ix < w {
                                    for c in 0..cin {
                                        s += x[((img * h + iy) * w + ix) * cin + c]
                                            * wt[((ki * kw + kj) * cin + c) * cout + o];
                                    }
                                }
                            }
                        }
                        out[((img * h + y) * w + xw) * cout + o] = s;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_naive_and_is_bp_invariant() {
        let (b, h, w, cin, kh, kw, cout) = (4, 6, 6, 3, 3, 3, 5);
        let x = randv(b * h * w * cin, 9);
        let wt = randv(kh * kw * cin * cout, 10);
        let p = GemmParams::with_threads(2);
        let want = conv_naive(&x, &wt, b, h, w, cin, kh, kw, cout);
        let full = conv2d_same(&x, &wt, b, h, w, cin, kh, kw, cout, b, &p);
        for (a, e) in full.iter().zip(&want) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
        for bp in [1, 2, 4, 0, 99] {
            let y = conv2d_same(&x, &wt, b, h, w, cin, kh, kw, cout, bp, &p);
            assert_eq!(y, full, "b_p={bp} must be bitwise invariant");
        }
    }

    #[test]
    fn conv_fused_epilogue_matches_separate_passes() {
        let (b, h, w, cin, kh, kw, cout) = (2, 4, 4, 3, 3, 3, 5);
        let x = randv(b * h * w * cin, 51);
        let wt = randv(kh * kw * cin * cout, 52);
        let bias = randv(cout, 53);
        let p = GemmParams::with_threads(2);
        let mut want = conv2d_same(&x, &wt, b, h, w, cin, kh, kw, cout, 1, &p);
        bias_add(&mut want, &bias, b * h * w, cout);
        relu_inplace(&mut want);
        let mut got = vec![0f32; b * h * w * cout];
        conv2d_fused_into(
            &mut got,
            &x,
            &wt,
            Some(&bias),
            true,
            b,
            h,
            w,
            cin,
            kh,
            kw,
            cout,
            2,
            &p,
        );
        assert_eq!(got, want, "fused conv bias+relu == separate passes");
    }

    #[test]
    fn wgrad_is_bp_invariant() {
        let (b, h, w, cin, kh, kw, cout) = (4, 4, 4, 2, 3, 3, 3);
        let x = randv(b * h * w * cin, 11);
        let g = randv(b * h * w * cout, 12);
        let p = GemmParams::with_threads(1);
        let full = conv_wgrad(&x, &g, b, h, w, cin, kh, kw, cout, b, &p);
        for bp in [1, 2] {
            let gw = conv_wgrad(&x, &g, b, h, w, cin, kh, kw, cout, bp, &p);
            assert_eq!(gw, full, "b_p={bp}");
        }
    }

    #[test]
    fn pool_and_bwd_route_max() {
        // One image, 2x2 -> 1x1, single channel.
        let x = [1.0f32, 3.0, 2.0, 0.5];
        let y = maxpool2x2(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![3.0]);
        let g = maxpool2x2_bwd(&x, &y, &[2.0], 1, 2, 2, 1);
        assert_eq!(g, vec![0.0, 2.0, 0.0, 0.0]);
        // Ties: every tied position receives the gradient.
        let xt = [7.0f32, 7.0, 1.0, 0.0];
        let yt = maxpool2x2(&xt, 1, 2, 2, 1);
        let gt = maxpool2x2_bwd(&xt, &yt, &[1.0], 1, 2, 2, 1);
        assert_eq!(gt, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn xent_uniform_and_confident() {
        let (loss, acc, grad) = softmax_xent(&[0.0; 8], &[0, 1], 2, 4);
        assert!((loss - (4f32).ln()).abs() < 1e-5);
        assert!((acc - 0.5).abs() < 1e-6); // first-occurrence argmax = 0
        // Uniform softmax grad: (1/n - onehot)/b.
        assert!((grad[0] - (0.25 - 1.0) / 2.0).abs() < 1e-6);
        assert!((grad[1] - 0.25 / 2.0).abs() < 1e-6);
        let (loss2, acc2, _) = softmax_xent(&[10.0, 0.0, 0.0], &[0], 1, 3);
        assert!(loss2 < 1e-3);
        assert_eq!(acc2, 1.0);
    }

    #[test]
    fn relu_bwd_accepts_post_activation_mask() {
        // The fused forward keeps only a = relu(z); backward masking by
        // a must match masking by z bit-for-bit.
        let z = [-1.5f32, -0.0, 0.0, 1e-30, 2.5, -3.0];
        let mut a = z;
        relu_inplace(&mut a);
        let g0 = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut by_z = g0;
        relu_bwd_inplace(&mut by_z, &z);
        let mut by_a = g0;
        relu_bwd_inplace(&mut by_a, &a);
        assert_eq!(by_z, by_a);
    }

    #[test]
    fn flip_w_rotates_and_swaps() {
        // k=1: flip is a pure [cin,cout] -> [cout,cin] transpose.
        let wt = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [1,1,2,3]
        let f = flip_w(&wt, 1, 1, 2, 3);
        assert_eq!(f, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]); // [1,1,3,2]
    }

    #[test]
    fn normalize_bp_rules() {
        assert_eq!(normalize_bp(32, 0), 32);
        assert_eq!(normalize_bp(32, 99), 32);
        assert_eq!(normalize_bp(32, 8), 8);
        assert_eq!(normalize_bp(32, 7), 4); // largest divisor <= 7
        assert_eq!(normalize_bp(1, 1), 1);
    }
}
