//! [`NativeBackend`] — pure-Rust CPU execution of the manifest's
//! artifact kinds over the packed kernels in [`super::kernels`].
//!
//! Shapes are read from the input literals themselves (not the manifest
//! entry), so one dispatcher serves every arch and batch size; the entry
//! contributes only its `kind` and the `b_p` lowering knob. The math is
//! a line-for-line port of python/compile/model.py (conv phase, recompute
//! -vjp conv backward, fused FC step) — parity against goldens generated
//! from those kernels is asserted to <= 1e-4 in `tests/it_backend.rs`.
//!
//! Memory discipline (the steady-state zero-allocation contract):
//!
//! * Input literals are **borrowed** (`Literal::as_f32`/`as_i32`), never
//!   copied into fresh `Vec`s.
//! * Every intermediate (activations, pooled maps, gradients in flight)
//!   lives in the per-thread [`super::scratch`] arena.
//! * Bias-add + ReLU ride the GEMM write-back ([`k::Epilogue`]) instead
//!   of separate full-tensor passes, and the pre-activations `z1`/`z2`
//!   are no longer materialized at all: `relu(z)` preserves exactly the
//!   sign information the backward mask needs (`a <= 0 <=> z <= 0`
//!   bit-for-bit), so the backward passes mask by the activations.
//! * Only artifact *outputs* allocate — their ownership leaves the
//!   backend inside the returned literals via `Literal::from_f32`
//!   (moved, not serialized through a byte copy).

use anyhow::{bail, ensure, Context, Result};

use super::kernels as k;
use super::scratch::{self, ScratchVec};
use super::{Backend, NATIVE_KINDS};
use crate::runtime::{ArtifactEntry, Runtime};

/// The native CPU kernel backend.
#[derive(Debug, Default)]
pub struct NativeBackend;

fn dims_of(l: &xla::Literal) -> Result<Vec<usize>> {
    match l.shape()? {
        xla::Shape::Array(a) => Ok(a.dims().iter().map(|&d| d as usize).collect()),
        other => bail!("native backend expects array literals, got {other:?}"),
    }
}

/// Borrow a literal's f32 storage (no copy).
fn f32_of(l: &xla::Literal) -> Result<&[f32]> {
    Ok(l.as_f32()?)
}

/// Borrow a literal's i32 storage (no copy).
fn i32_of(l: &xla::Literal) -> Result<&[i32]> {
    Ok(l.as_i32()?)
}

/// Move an output buffer into a literal (no copy).
fn lit(dims: &[usize], data: Vec<f32>) -> Result<xla::Literal> {
    Ok(xla::Literal::from_f32(dims, data)?)
}

fn scalar(v: f32) -> Result<xla::Literal> {
    lit(&[], vec![v])
}

/// The two-phase CNN's dimensions, derived from the input literals
/// (x [b,h,w,cin], wc1 [k,k,cin,c1], wc2 [k,k,c1,c2], wf1 [feat,f1],
/// wf2 [f1,ncls]) the way python model.Arch derives them.
#[derive(Clone, Copy, Debug)]
struct Dims {
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    c1: usize,
    c2: usize,
    feat: usize,
}

impl Dims {
    fn conv(x: &[usize], wc1: &[usize], wc2: &[usize]) -> Result<Self> {
        ensure!(x.len() == 4 && wc1.len() == 4 && wc2.len() == 4, "conv input ranks");
        let (b, h, w, cin) = (x[0], x[1], x[2], x[3]);
        ensure!(wc1[2] == cin, "wc1 cin {} != x cin {cin}", wc1[2]);
        ensure!(wc1[0] == wc1[1] && wc1[0] == wc2[0], "square kernels");
        ensure!(wc2[2] == wc1[3], "wc2 cin != c1");
        ensure!(h % 4 == 0 && w % 4 == 0, "two pool2 stages need h,w % 4 == 0");
        let (c1, c2) = (wc1[3], wc2[3]);
        Ok(Self { b, h, w, cin, k: wc1[0], c1, c2, feat: (h / 4) * (w / 4) * c2 })
    }
}

/// Forward conv-phase intermediates kept for the recompute backward.
/// Post-activation tensors only: the fused conv epilogue never
/// materializes the pre-activations, and the ReLU backward mask taken
/// from `a = relu(z)` is bit-identical to the one taken from `z`.
/// All four live in the scratch arena; `conv_fwd` copies `p2` out.
struct ConvTrace {
    a1: ScratchVec,
    p1: ScratchVec,
    a2: ScratchVec,
    p2: ScratchVec,
}

fn conv_phase(
    x: &[f32],
    wc1: &[f32],
    bc1: &[f32],
    wc2: &[f32],
    bc2: &[f32],
    d: Dims,
    b_p: usize,
    gp: &k::GemmParams,
) -> ConvTrace {
    let (h2, w2) = (d.h / 2, d.w / 2);
    let mut a1 = scratch::take(d.b * d.h * d.w * d.c1);
    k::conv2d_fused_into(
        &mut a1,
        x,
        wc1,
        Some(bc1),
        true,
        d.b,
        d.h,
        d.w,
        d.cin,
        d.k,
        d.k,
        d.c1,
        b_p,
        gp,
    );
    let mut p1 = scratch::take(d.b * h2 * w2 * d.c1);
    k::maxpool2x2_into(&mut p1, &a1, d.b, d.h, d.w, d.c1);
    let mut a2 = scratch::take(d.b * h2 * w2 * d.c2);
    k::conv2d_fused_into(
        &mut a2,
        &p1,
        wc2,
        Some(bc2),
        true,
        d.b,
        h2,
        w2,
        d.c1,
        d.k,
        d.k,
        d.c2,
        b_p,
        gp,
    );
    let mut p2 = scratch::take(d.b * (h2 / 2) * (w2 / 2) * d.c2);
    k::maxpool2x2_into(&mut p2, &a2, d.b, h2, w2, d.c2);
    ConvTrace { a1, p1, a2, p2 }
}

/// Chain rule back through pool/relu/conv twice (model.py `conv_bwd`).
/// Returns (gwc1, gbc1, gwc2, gbc2) flat — these are outputs, so they
/// are plain `Vec`s whose ownership moves into the result literals.
#[allow(clippy::too_many_arguments)]
fn conv_backward(
    x: &[f32],
    wc2: &[f32],
    t: &ConvTrace,
    g_act: &[f32],
    d: Dims,
    b_p: usize,
    gp: &k::GemmParams,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (h2, w2) = (d.h / 2, d.w / 2);
    // g_act [b, feat] IS g_p2 [b, h/4, w/4, c2] (row-major reshape).
    let mut g_a2 = scratch::take(d.b * h2 * w2 * d.c2);
    k::maxpool2x2_bwd_into(&mut g_a2, &t.a2, &t.p2, g_act, d.b, h2, w2, d.c2);
    k::relu_bwd_inplace(&mut g_a2, &t.a2); // a2-mask == z2-mask; now g_z2
    let mut gwc2 = vec![0f32; d.k * d.k * d.c1 * d.c2];
    k::conv_wgrad_into(&mut gwc2, &t.p1, &g_a2, d.b, h2, w2, d.c1, d.k, d.k, d.c2, b_p, gp);
    let gbc2 = k::colsum(&g_a2, d.b * h2 * w2, d.c2);
    let mut wflip = scratch::take(d.k * d.k * d.c2 * d.c1);
    k::flip_w_into(&mut wflip, wc2, d.k, d.k, d.c1, d.c2);
    let mut g_p1 = scratch::take(d.b * h2 * w2 * d.c1);
    k::conv2d_fused_into(
        &mut g_p1,
        &g_a2,
        &wflip,
        None,
        false,
        d.b,
        h2,
        w2,
        d.c2,
        d.k,
        d.k,
        d.c1,
        b_p,
        gp,
    );
    let mut g_a1 = scratch::take(d.b * d.h * d.w * d.c1);
    k::maxpool2x2_bwd_into(&mut g_a1, &t.a1, &t.p1, &g_p1, d.b, d.h, d.w, d.c1);
    k::relu_bwd_inplace(&mut g_a1, &t.a1); // a1-mask == z1-mask; now g_z1
    let mut gwc1 = vec![0f32; d.k * d.k * d.cin * d.c1];
    k::conv_wgrad_into(&mut gwc1, x, &g_a1, d.b, d.h, d.w, d.cin, d.k, d.k, d.c1, b_p, gp);
    let gbc1 = k::colsum(&g_a1, d.b * d.h * d.w, d.c1);
    (gwc1, gbc1, gwc2, gbc2)
}

/// FC forward (model.py `_fc_phase`) with bias/ReLU fused into the GEMM
/// write-backs. Returns (h, logits) in scratch; `h = relu(z1)` carries
/// the backward mask, so `z1` itself is never materialized.
#[allow(clippy::too_many_arguments)]
fn fc_forward(
    act: &[f32],
    wf1: &[f32],
    bf1: &[f32],
    wf2: &[f32],
    bf2: &[f32],
    b: usize,
    feat: usize,
    f1: usize,
    ncls: usize,
    gp: &k::GemmParams,
) -> (ScratchVec, ScratchVec) {
    let mut h = scratch::take(b * f1);
    k::gemm_fused_into(&mut h, act, wf1, b, feat, f1, gp, k::Epilogue::BiasRelu(bf1));
    let mut logits = scratch::take(b * ncls);
    k::gemm_fused_into(&mut logits, &h, wf2, b, f1, ncls, gp, k::Epilogue::Bias(bf2));
    (h, logits)
}

/// Fused FC fwd + bwd + loss (model.py `fc_step`). Returns
/// (loss, acc, g_act, gwf1, gbf1, gwf2, gbf2); the `Vec`s are outputs.
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn fc_step(
    act: &[f32],
    labels: &[i32],
    wf1: &[f32],
    bf1: &[f32],
    wf2: &[f32],
    bf2: &[f32],
    b: usize,
    feat: usize,
    f1: usize,
    ncls: usize,
    gp: &k::GemmParams,
) -> (f32, f32, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (h, logits) = fc_forward(act, wf1, bf1, wf2, bf2, b, feat, f1, ncls, gp);
    let mut g_logits = scratch::take(b * ncls);
    let (loss, acc) = k::softmax_xent_into(&mut g_logits, &logits, labels, b, ncls);
    let mut gwf2 = vec![0f32; f1 * ncls];
    k::gemm_tn_acc(&mut gwf2, &h, &g_logits, b, f1, ncls, gp.threads);
    let gbf2 = k::colsum(&g_logits, b, ncls);
    let mut g_h = scratch::take(b * f1);
    k::gemm_nt_into(&mut g_h, &g_logits, wf2, b, ncls, f1, gp.threads);
    k::relu_bwd_inplace(&mut g_h, &h); // h-mask == z1-mask; now g_z1
    let mut gwf1 = vec![0f32; feat * f1];
    k::gemm_tn_acc(&mut gwf1, act, &g_h, b, feat, f1, gp.threads);
    let gbf1 = k::colsum(&g_h, b, f1);
    let mut g_act = vec![0f32; b * feat];
    k::gemm_nt_into(&mut g_act, &g_h, wf1, b, f1, feat, gp.threads);
    (loss, acc, g_act, gwf1, gbf1, gwf2, gbf2)
}

/// Read (dims, borrowed data) for a conv-parameter quad
/// [wc1, bc1, wc2, bc2].
type ConvQuad<'a> = (Vec<usize>, Vec<usize>, &'a [f32], &'a [f32], &'a [f32], &'a [f32]);

fn conv_quad<'a>(lits: &[&'a xla::Literal]) -> Result<ConvQuad<'a>> {
    let wc1d = dims_of(lits[0])?;
    let wc2d = dims_of(lits[2])?;
    Ok((
        wc1d,
        wc2d,
        f32_of(lits[0])?,
        f32_of(lits[1])?,
        f32_of(lits[2])?,
        f32_of(lits[3])?,
    ))
}

/// FC dims from wf1 [feat, f1] and wf2 [f1, ncls].
fn fc_dims(wf1: &xla::Literal, wf2: &xla::Literal) -> Result<(usize, usize, usize)> {
    let d1 = dims_of(wf1)?;
    let d2 = dims_of(wf2)?;
    ensure!(d1.len() == 2 && d2.len() == 2 && d1[1] == d2[0], "fc weight shapes");
    Ok((d1[0], d1[1], d2[1]))
}

impl NativeBackend {
    fn run(&self, entry: &ArtifactEntry, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let gp = k::GemmParams::default();
        let bp_knob = entry.b_p.unwrap_or(0);
        match entry.kind.as_str() {
            "conv_fwd" => {
                ensure!(inputs.len() == 5, "conv_fwd takes (x, wc1, bc1, wc2, bc2)");
                let xd = dims_of(inputs[0])?;
                let (wc1d, wc2d, wc1, bc1, wc2, bc2) = conv_quad(&inputs[1..5])?;
                let d = Dims::conv(&xd, &wc1d, &wc2d)?;
                let b_p = k::normalize_bp(d.b, bp_knob);
                let x = f32_of(inputs[0])?;
                let t = conv_phase(x, wc1, bc1, wc2, bc2, d, b_p, &gp);
                Ok(vec![lit(&[d.b, d.feat], t.p2.to_vec())?])
            }
            "conv_bwd" => {
                ensure!(inputs.len() == 6, "conv_bwd takes (x, conv params, g_act)");
                let xd = dims_of(inputs[0])?;
                let (wc1d, wc2d, wc1, bc1, wc2, bc2) = conv_quad(&inputs[1..5])?;
                let d = Dims::conv(&xd, &wc1d, &wc2d)?;
                let b_p = k::normalize_bp(d.b, bp_knob);
                let x = f32_of(inputs[0])?;
                let g_act = f32_of(inputs[5])?;
                ensure!(g_act.len() == d.b * d.feat, "g_act shape");
                let t = conv_phase(x, wc1, bc1, wc2, bc2, d, b_p, &gp);
                let (gwc1, gbc1, gwc2, gbc2) = conv_backward(x, wc2, &t, g_act, d, b_p, &gp);
                Ok(vec![
                    lit(&wc1d, gwc1)?,
                    lit(&[d.c1], gbc1)?,
                    lit(&wc2d, gwc2)?,
                    lit(&[d.c2], gbc2)?,
                ])
            }
            "fc_step" => {
                ensure!(inputs.len() == 6, "fc_step takes (act, labels, fc params)");
                let ad = dims_of(inputs[0])?;
                ensure!(ad.len() == 2, "act rank");
                let (feat, f1, ncls) = fc_dims(inputs[2], inputs[4])?;
                ensure!(ad[1] == feat, "act feat {} != wf1 feat {feat}", ad[1]);
                let act = f32_of(inputs[0])?;
                let labels = i32_of(inputs[1])?;
                ensure!(labels.len() == ad[0], "labels length");
                let (wf1, bf1, wf2, bf2) = (
                    f32_of(inputs[2])?,
                    f32_of(inputs[3])?,
                    f32_of(inputs[4])?,
                    f32_of(inputs[5])?,
                );
                let (loss, acc, g_act, gwf1, gbf1, gwf2, gbf2) =
                    fc_step(act, labels, wf1, bf1, wf2, bf2, ad[0], feat, f1, ncls, &gp);
                Ok(vec![
                    scalar(loss)?,
                    scalar(acc)?,
                    lit(&ad, g_act)?,
                    lit(&[feat, f1], gwf1)?,
                    lit(&[f1], gbf1)?,
                    lit(&[f1, ncls], gwf2)?,
                    lit(&[ncls], gbf2)?,
                ])
            }
            "full_step" | "infer" => {
                let infer = entry.kind == "infer";
                let np = if infer { 9 } else { 10 };
                ensure!(
                    inputs.len() == np,
                    "{} takes x{} and 8 params",
                    entry.kind,
                    if infer { "" } else { ", labels" }
                );
                let xd = dims_of(inputs[0])?;
                let poff = if infer { 1 } else { 2 };
                let (wc1d, wc2d, wc1, bc1, wc2, bc2) = conv_quad(&inputs[poff..poff + 4])?;
                let d = Dims::conv(&xd, &wc1d, &wc2d)?;
                let b_p = k::normalize_bp(d.b, bp_knob);
                let (feat, f1, ncls) = fc_dims(inputs[poff + 4], inputs[poff + 6])?;
                ensure!(feat == d.feat, "fc feat {feat} != conv feat {}", d.feat);
                let x = f32_of(inputs[0])?;
                let (wf1, bf1, wf2, bf2) = (
                    f32_of(inputs[poff + 4])?,
                    f32_of(inputs[poff + 5])?,
                    f32_of(inputs[poff + 6])?,
                    f32_of(inputs[poff + 7])?,
                );
                let t = conv_phase(x, wc1, bc1, wc2, bc2, d, b_p, &gp);
                if infer {
                    let (_h, logits) =
                        fc_forward(&t.p2, wf1, bf1, wf2, bf2, d.b, feat, f1, ncls, &gp);
                    return Ok(vec![lit(&[d.b, ncls], logits.to_vec())?]);
                }
                let labels = i32_of(inputs[1])?;
                ensure!(labels.len() == d.b, "labels length");
                let (loss, acc, g_act, gwf1, gbf1, gwf2, gbf2) =
                    fc_step(&t.p2, labels, wf1, bf1, wf2, bf2, d.b, feat, f1, ncls, &gp);
                let (gwc1, gbc1, gwc2, gbc2) = conv_backward(x, wc2, &t, &g_act, d, b_p, &gp);
                Ok(vec![
                    scalar(loss)?,
                    scalar(acc)?,
                    lit(&wc1d, gwc1)?,
                    lit(&[d.c1], gbc1)?,
                    lit(&wc2d, gwc2)?,
                    lit(&[d.c2], gbc2)?,
                    lit(&[feat, f1], gwf1)?,
                    lit(&[f1], gbf1)?,
                    lit(&[f1, ncls], gwf2)?,
                    lit(&[ncls], gbf2)?,
                ])
            }
            "convchunk" | "convbench" => {
                ensure!(inputs.len() == 2, "{} takes (x, w)", entry.kind);
                let xd = dims_of(inputs[0])?;
                let wd = dims_of(inputs[1])?;
                ensure!(xd.len() == 4 && wd.len() == 4, "conv bench ranks");
                let (b, h, w, cin) = (xd[0], xd[1], xd[2], xd[3]);
                ensure!(wd[2] == cin, "bench w cin");
                let b_p = k::normalize_bp(b, bp_knob);
                let x = f32_of(inputs[0])?;
                let wt = f32_of(inputs[1])?;
                let y = k::conv2d_same(x, wt, b, h, w, cin, wd[0], wd[1], wd[3], b_p, &gp);
                Ok(vec![lit(&[b, h, w, wd[3]], y)?])
            }
            "gemm" => {
                ensure!(inputs.len() == 2, "gemm takes (a, b)");
                let adim = dims_of(inputs[0])?;
                let bdim = dims_of(inputs[1])?;
                ensure!(
                    adim.len() == 2 && bdim.len() == 2 && adim[1] == bdim[0],
                    "gemm shapes {adim:?} x {bdim:?}"
                );
                let a = f32_of(inputs[0])?;
                let b = f32_of(inputs[1])?;
                let c = k::gemm(a, b, adim[0], adim[1], bdim[1], &gp);
                Ok(vec![lit(&[adim[0], bdim[1]], c)?])
            }
            other => bail!(
                "native backend has no kernel for artifact kind {other:?} \
                 (supported: {NATIVE_KINDS:?})"
            ),
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, entry: &ArtifactEntry) -> bool {
        NATIVE_KINDS.contains(&entry.kind.as_str())
    }

    fn execute(
        &self,
        _rt: &Runtime,
        entry: &ArtifactEntry,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.run(entry, inputs)
            .with_context(|| format!("native backend executing {}", entry.name))
    }
}
