//! Per-thread, grow-only scratch arena for kernel temporaries.
//!
//! Every sizable temporary on the native hot path — im2col `D-hat`
//! panels, packed GEMM A/B panels, unpacked-GEMM accumulator tiles,
//! conv/pool/softmax intermediates — is taken from here instead of
//! `vec![0f32; …]`, so steady-state training performs **zero heap
//! allocations per iteration**: after a warmup iteration has grown each
//! thread's free list to the working set, every `take` is served by
//! reusing a previously returned buffer.
//!
//! Ownership rules (documented in DESIGN.md §Backends):
//!
//! * A buffer is owned by exactly one [`ScratchVec`] handle at a time;
//!   dropping the handle returns the buffer to the *current* thread's
//!   free list. Handles taken inside a pool worker therefore stay in
//!   that worker's arena — and because the pool's chunk→lane partition
//!   is static (see [`super::pool`]), each worker sees the same request
//!   sizes every iteration and converges to zero misses.
//! * `take(len)` is best-fit: the smallest free buffer with
//!   `capacity >= len` is reused (cleared and zero-filled — `resize` on
//!   sufficient capacity never reallocates). No fit means a fresh
//!   allocation, which is counted as a **miss**.
//! * Artifact *outputs* are deliberately NOT arena-backed: their
//!   ownership leaves the backend inside the returned `xla::Literal`s
//!   (moved, not copied, via `Literal::from_f32`), so recycling them
//!   here would be a use-after-free by construction. The zero-alloc
//!   claim (and the `invariants` counter below) covers every scratch
//!   buffer and intermediate, not the handful of output vectors whose
//!   ownership transfers to the caller.
//!
//! With the `invariants` feature, [`alloc_count`] exposes the global
//! miss counter; `tests/it_alloc.rs` asserts it stays flat across
//! steady-state training iterations.

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};

thread_local! {
    static FREE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

#[cfg(feature = "invariants")]
static MISSES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Total scratch allocations (arena misses) across all threads since
/// process start. Flat across iterations == zero per-iteration heap
/// allocations on the kernel path.
#[cfg(feature = "invariants")]
pub fn alloc_count() -> u64 {
    MISSES.load(std::sync::atomic::Ordering::SeqCst)
}

fn count_miss() {
    #[cfg(feature = "invariants")]
    MISSES.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
}

/// A zeroed `len`-element f32 buffer borrowed from the current thread's
/// arena; returns itself on drop.
pub fn take(len: usize) -> ScratchVec {
    let mut buf = FREE.with(|f| {
        let mut free = f.borrow_mut();
        let mut best: Option<usize> = None;
        for (i, b) in free.iter().enumerate() {
            if b.capacity() >= len
                && best.is_none_or(|j: usize| free[j].capacity() > b.capacity())
            {
                best = Some(i);
            }
        }
        match best {
            Some(i) => free.swap_remove(i),
            None => {
                count_miss();
                Vec::with_capacity(len.max(1))
            }
        }
    });
    buf.clear();
    buf.resize(len, 0.0);
    ScratchVec { buf }
}

/// RAII handle over an arena buffer; derefs to `[f32]`.
#[derive(Debug)]
pub struct ScratchVec {
    buf: Vec<f32>,
}

impl Deref for ScratchVec {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl DerefMut for ScratchVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Drop for ScratchVec {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() > 0 {
            FREE.with(|f| f.borrow_mut().push(buf));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroes_and_reuses() {
        {
            let mut a = take(16);
            a.iter_mut().for_each(|v| *v = 7.0);
            assert_eq!(a.len(), 16);
        } // returned
        let b = take(8);
        assert!(b.iter().all(|&v| v == 0.0), "reused buffers are re-zeroed");
        assert!(b.len() == 8);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        // Seed the arena with a small and a large buffer.
        drop(take(1000));
        drop(take(10));
        let s = take(8);
        assert!(s.buf.capacity() < 1000, "best fit picked the small buffer");
        let l = take(900);
        assert!(l.buf.capacity() >= 1000, "large request reuses the large buffer");
    }

    #[cfg(feature = "invariants")]
    #[test]
    fn misses_are_counted_and_converge() {
        // Unique large size so other tests on this thread can't satisfy it.
        let n = 777_777;
        let before = alloc_count();
        drop(take(n));
        let after_first = alloc_count();
        assert!(after_first > before, "first take of a new size is a miss");
        drop(take(n));
        // The second identical take on this thread reuses the buffer.
        // (Other test threads may miss concurrently; only assert ours.)
        let _ = after_first;
    }
}
