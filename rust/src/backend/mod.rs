//! Pluggable execution backends for compiled artifacts.
//!
//! The paper treats each device as a black box with a measured rate; this
//! module is that boundary in code. A [`Backend`] turns a manifest
//! [`ArtifactEntry`] plus input literals into output literals. Two
//! implementations ship today:
//!
//! * [`StubBackend`] — the original path: compile the artifact's HLO text
//!   through the vendored PJRT surface and execute it there. With the
//!   offline stub this compiles but refuses to execute; against a real
//!   PJRT build it runs on whatever device the client owns.
//! * [`NativeBackend`] — pure-Rust CPU kernels (blocked GEMM, im2col conv
//!   with the paper's `b_p` lowering knob, max-pool, fused
//!   softmax+cross-entropy) that execute the same artifact kinds for
//!   real. See [`kernels`] for the schedule details.
//!
//! Selection is per artifact and per device group: `--backend auto`
//! (default) picks native whenever the artifact's kind is supported and
//! falls back to the stub otherwise, so adding a new artifact kind
//! degrades to the old behavior instead of breaking.

use anyhow::{bail, Result};

#[cfg(feature = "xla")]
use crate::runtime::{ArtifactEntry, Runtime};

pub mod kernels;
#[cfg(feature = "xla")]
mod native;
pub mod pool;
pub mod scratch;

#[cfg(feature = "xla")]
pub use native::NativeBackend;

/// Artifact kinds the native backend can execute (kept available to the
/// pure layers so `RunSpec` validation can reason about it offline).
pub const NATIVE_KINDS: &[&str] = &[
    "conv_fwd", "conv_bwd", "fc_step", "full_step", "infer", "convchunk", "convbench",
    "gemm",
];

/// An execution engine for compiled artifacts.
///
/// Implementations must be `Send + Sync`: one instance is shared by every
/// compute group and the merged-FC server across scheduler threads.
#[cfg(feature = "xla")]
pub trait Backend: Send + Sync {
    /// Stable short name recorded in run outcomes ("stub", "native").
    fn name(&self) -> &'static str;

    /// Whether this backend can execute the given artifact.
    fn supports(&self, entry: &ArtifactEntry) -> bool;

    /// Execute the artifact on the given inputs, returning one literal
    /// per manifest output in order.
    fn execute(
        &self,
        rt: &Runtime,
        entry: &ArtifactEntry,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>>;
}

/// User-facing backend selection policy (`--backend`, `RunSpec.backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// Per artifact: native when its kind is supported, stub otherwise.
    #[default]
    Auto,
    /// Always the PJRT(-stub) path.
    Stub,
    /// Always the native CPU kernels; unsupported kinds error.
    Native,
}

impl BackendChoice {
    /// Parse a `--backend` / `RunSpec.backend` value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "stub" => Ok(Self::Stub),
            "native" => Ok(Self::Native),
            other => bail!("unknown backend {other:?} (expected stub|native|auto)"),
        }
    }

    /// The canonical spelling of this choice.
    pub fn name(self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Stub => "stub",
            Self::Native => "native",
        }
    }
}

/// A resolved backend identity — what [`BackendChoice::Auto`] collapses
/// to once an artifact (and the device kind that will run it) is known.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendSel {
    Stub,
    Native,
}

impl BackendSel {
    pub fn name(self) -> &'static str {
        match self {
            Self::Stub => "stub",
            Self::Native => "native",
        }
    }
}

/// The PJRT(-stub) path: compile the artifact's HLO and execute it on the
/// runtime's PJRT client. Kept as a thin wrapper so the compile cache and
/// executable ownership stay inside [`Runtime`].
#[derive(Debug, Default)]
pub struct StubBackend;

#[cfg(feature = "xla")]
impl Backend for StubBackend {
    fn name(&self) -> &'static str {
        "stub"
    }

    fn supports(&self, _entry: &ArtifactEntry) -> bool {
        // The stub compiles anything with an HLO file; whether execution
        // succeeds depends on the linked PJRT being real.
        true
    }

    fn execute(
        &self,
        rt: &Runtime,
        entry: &ArtifactEntry,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        rt.stub_execute_refs(&entry.name, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parses_and_round_trips() {
        for s in ["auto", "stub", "native"] {
            assert_eq!(BackendChoice::parse(s).unwrap().name(), s);
        }
        assert!(BackendChoice::parse("gpu").is_err());
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn native_supports_known_kinds_only() {
        let entry = |kind: &str| ArtifactEntry {
            name: "t".into(),
            file: "t.hlo".into(),
            inputs: vec![],
            outputs: vec![],
            arch: None,
            variant: None,
            kind: kind.into(),
            batch: None,
            b_p: None,
            n: None,
            gflops: None,
            lowered_bytes: None,
        };
        let nb = NativeBackend;
        for k in NATIVE_KINDS {
            assert!(nb.supports(&entry(k)), "{k}");
        }
        assert!(!nb.supports(&entry("mystery_op")));
        assert!(StubBackend.supports(&entry("mystery_op")));
    }
}
