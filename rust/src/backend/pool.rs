//! Persistent worker pool for the native CPU kernels.
//!
//! PR 7's kernels spawned fresh `std::thread::scope` threads on every
//! GEMM call — tens of microseconds of clone/TLB churn per call on a hot
//! path that executes thousands of times per epoch. This pool parks a
//! fixed set of workers once per process and hands them chunked jobs
//! through a generation-counted condvar handshake.
//!
//! Design properties the kernels rely on:
//!
//! * **Deterministic static partition** (no work stealing): chunk `c` of
//!   a `run(nchunks, f)` call always executes on lane `c % lanes`, where
//!   lane 0 is the submitting thread itself. Which lane runs a chunk
//!   never affects values — chunks write disjoint outputs — but the
//!   static map keeps scheduling reproducible and keeps each worker's
//!   thread-local scratch arena (see [`super::scratch`]) warm with the
//!   same buffer sizes every iteration.
//! * **Serialized submission**: `run` holds an internal lock for the
//!   duration of the job, so concurrent callers (e.g. the threaded
//!   engine executing two artifacts at once) queue rather than
//!   interleave on the same workers.
//! * **Nested submission runs inline**: a chunk closure that itself
//!   calls `run` (conv chunk -> inner GEMM) executes the nested job on
//!   the current thread instead of deadlocking on the submission lock.
//!   The thread-local [`in_pool`] flag implements this.
//! * The pool never outlives a job's borrows: `run` blocks until every
//!   worker has finished the generation, which is what makes handing
//!   workers a raw pointer to the caller's stack closure sound.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Type-erased view of one submitted job: `call(data, chunk)` runs one
/// chunk of the caller's closure, `data` pointing at that closure on the
/// submitting thread's stack.
///
/// SAFETY: `call` may only be invoked while the submitting `run` call is
/// blocked on the generation barrier (it is the shim monomorphized for
/// the closure's real type, and `data` borrows that closure).
#[derive(Clone, Copy)]
struct Job {
    call: unsafe fn(*const (), usize),
    data: *const (),
    nchunks: usize,
}

// SAFETY: `data` points at a closure owned by the thread blocked inside
// `WorkerPool::run` until every worker finishes the generation, so the
// pointer never dangles while a worker can observe it; the closure is
// `Sync`, so sharing it across worker threads is sound.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per submitted job; workers detect work by comparing
    /// against the last generation they executed.
    generation: u64,
    job: Option<Job>,
    /// Workers still executing the current generation.
    active: usize,
    /// A worker chunk panicked; the submitter re-raises after the barrier.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new generation.
    work_cv: Condvar,
    /// The submitter waits here for `active` to reach zero.
    done_cv: Condvar,
}

/// A fixed-size pool of parked worker threads plus the submitting lane.
pub struct WorkerPool {
    /// Total lanes including the submitting thread (so `lanes - 1`
    /// parked workers). `lanes == 1` means every job runs inline.
    lanes: usize,
    shared: &'static Shared,
    /// Serializes `run` calls from different threads.
    run_lock: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

thread_local! {
    /// True while this thread is executing pool work (either as a
    /// worker lane or as the submitting lane 0). Nested `run` calls
    /// observe it and execute inline.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Is the current thread inside a pool job? (Nested kernel calls use
/// this to skip re-submission and stay on the current lane.)
pub fn in_pool() -> bool {
    IN_POOL.with(|c| c.get())
}

impl WorkerPool {
    /// Build a pool with `lanes` total execution lanes (clamped to
    /// 1..=64). `lanes - 1` worker threads are spawned and parked.
    pub fn new(lanes: usize) -> Self {
        let lanes = lanes.clamp(1, 64);
        // Leaked on purpose: worker lifetime == process lifetime for the
        // global pool, and explicit pools join their workers in Drop
        // (the tiny Shared block is the only thing that outlives them).
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }));
        let handles = (1..lanes)
            .map(|lane| {
                std::thread::Builder::new()
                    .name(format!("omnivore-kernel-{lane}"))
                    .spawn(move || worker_loop(shared, lane, lanes))
                    .expect("spawning kernel pool worker")
            })
            .collect();
        Self { lanes, shared, run_lock: Mutex::new(()), handles }
    }

    /// Total execution lanes (submitting thread included).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Execute `f(c)` for every chunk `c in 0..nchunks`, chunk `c` on
    /// lane `c % lanes`. Blocks until all chunks are done. Chunks MUST
    /// write disjoint data (each index runs exactly once; the compiler
    /// only sees `&F`, so interior writes go through raw pointers the
    /// caller derives per chunk). Runs inline when the pool has a single
    /// lane, the job has a single chunk, or the current thread is
    /// already a pool lane.
    pub fn run<F>(&self, nchunks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if nchunks == 0 {
            return;
        }
        if self.lanes == 1 || nchunks == 1 || in_pool() {
            for c in 0..nchunks {
                f(c);
            }
            return;
        }
        /// Monomorphized shim giving workers a way to call `F` through a
        /// type-erased pointer. SAFETY contract: `data` was derived from
        /// `&f` in `run` below, which does not return until the
        /// completion barrier passes, so the borrow is always live.
        unsafe fn call_shim<F: Fn(usize) + Sync>(data: *const (), chunk: usize) {
            let f = &*(data as *const F);
            f(chunk);
        }
        let job =
            Job { call: call_shim::<F>, data: &f as *const F as *const (), nchunks };
        let _submit = self.run_lock.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.generation += 1;
            st.job = Some(job);
            st.active = self.lanes - 1;
            st.panicked = false;
            self.shared.work_cv.notify_all();
        }
        // Lane 0 = the submitting thread; mark it as in-pool so nested
        // kernel calls inside `f` execute inline instead of deadlocking
        // on `run_lock`. Catch panics so the generation barrier always
        // completes before this frame (and the closure workers borrow)
        // can unwind away.
        IN_POOL.with(|c| c.set(true));
        let lane0 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut c = 0;
            while c < nchunks {
                f(c);
                c += self.lanes;
            }
        }));
        IN_POOL.with(|c| c.set(false));
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let poisoned = st.panicked;
        drop(st);
        if let Err(payload) = lane0 {
            std::panic::resume_unwind(payload);
        }
        assert!(!poisoned, "a kernel pool worker panicked while running a chunk");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &'static Shared, lane: usize, lanes: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen {
                    seen = st.generation;
                    break st.job.expect("generation bumped without a job");
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        IN_POOL.with(|c| c.set(true));
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut c = lane;
            while c < job.nchunks {
                // SAFETY: the submitting thread is blocked in `run`
                // until this generation's barrier clears, so the closure
                // behind `job.data` is alive; `call` is the shim
                // monomorphized for the closure's real type.
                unsafe { (job.call)(job.data, c) };
                c += lanes;
            }
        }))
        .is_err();
        IN_POOL.with(|c| c.set(false));
        let mut st = shared.state.lock().unwrap();
        if panicked {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Desired size for the global pool before it is first built (0 = use
/// [`super::kernels::default_threads`]).
static REQUESTED_LANES: AtomicUsize = AtomicUsize::new(0);
static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// Size the process-global pool. Effective only before the pool's first
/// use (the pool is built lazily); afterwards the existing size wins.
/// Returns the size the global pool has / will have.
pub fn set_global_lanes(n: usize) -> usize {
    if let Some(p) = GLOBAL.get() {
        return p.lanes();
    }
    REQUESTED_LANES.store(n.clamp(1, 64), Ordering::SeqCst);
    // Build it now so the recorded size is the real one even if another
    // thread races a different request in.
    global().lanes()
}

/// The global pool's lane count if it has been built, `None` otherwise
/// (never forces a build — outcome recording must not spawn workers for
/// runs that executed no native kernel).
pub fn current_global_lanes() -> Option<usize> {
    GLOBAL.get().map(WorkerPool::lanes)
}

/// The process-global kernel pool, built on first use and sized by
/// [`set_global_lanes`] / `OMNIVORE_THREADS` / host parallelism.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        let n = match REQUESTED_LANES.load(Ordering::SeqCst) {
            0 => super::kernels::default_threads(),
            n => n,
        };
        WorkerPool::new(n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = WorkerPool::new(4);
        for nchunks in [1usize, 2, 3, 4, 7, 16, 33] {
            let hits: Vec<AtomicU64> =
                (0..nchunks).map(|_| AtomicU64::new(0)).collect();
            pool.run(nchunks, |c| {
                hits[c].fetch_add(1, Ordering::SeqCst);
            });
            for (c, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {c} of {nchunks}");
            }
        }
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.lanes(), 1);
        let sum = AtomicU64::new(0);
        pool.run(5, |c| {
            assert!(!in_pool(), "1-lane pools never mark threads as pool lanes");
            sum.fetch_add(c as u64 + 1, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = WorkerPool::new(3);
        let outer_hits: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        let inner_total = AtomicU64::new(0);
        pool.run(6, |c| {
            outer_hits[c].fetch_add(1, Ordering::SeqCst);
            assert!(in_pool());
            // A nested submission must not deadlock; it runs inline.
            pool.run(4, |i| {
                inner_total.fetch_add(i as u64 + 1, Ordering::SeqCst);
            });
        });
        assert!(outer_hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert_eq!(inner_total.load(Ordering::SeqCst), 6 * 10);
        assert!(!in_pool());
    }

    #[test]
    fn disjoint_writes_through_raw_parts() {
        let pool = WorkerPool::new(4);
        let mut buf = vec![0u64; 40];
        let ptr = buf.as_mut_ptr() as usize;
        pool.run(10, |c| {
            // SAFETY: chunk c owns the disjoint range [4c, 4c+4); every
            // chunk index executes exactly once, so no two writers alias.
            let s = unsafe { std::slice::from_raw_parts_mut((ptr as *mut u64).add(4 * c), 4) };
            for (i, v) in s.iter_mut().enumerate() {
                *v = (c * 4 + i) as u64;
            }
        });
        assert_eq!(buf, (0..40).map(|i| i as u64).collect::<Vec<_>>());
    }

    #[test]
    fn global_pool_is_built_once() {
        let a = global().lanes();
        let b = set_global_lanes(a + 7);
        assert_eq!(a, b, "resizing after first use keeps the existing pool");
    }
}
