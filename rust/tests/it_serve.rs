//! Integration tests for `omnivore serve` (DESIGN.md §Serving): an
//! in-process daemon on an ephemeral port, driven over real sockets by
//! a hand-rolled one-request-per-connection HTTP client (mirroring the
//! daemon's own one-exchange model).
//!
//! Covers the PR's acceptance gates: submit→poll→stored-outcome
//! roundtrip with the outcome bit-identical to the same spec executed
//! the CLI way (modulo wall-clock fields), admission control
//! serializing two runs whose combined demand exceeds the fleet,
//! per-client 429s (token bucket + run quota), mid-run cancellation
//! returning its lease, and malformed-request 4xx mapping.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use omnivore::api::{resolve_artifacts_dir, RunSpec, RunStore};
use omnivore::runtime::Runtime;
use omnivore::serve::{Daemon, ServeConfig};
use omnivore::util::json::Json;

// -- tiny HTTP client --------------------------------------------------------

/// One exchange: write `req` verbatim, read to EOF (the daemon always
/// closes), return (status, body-after-blank-line).
fn http(addr: SocketAddr, req: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read response");
    let status = buf
        .split(' ')
        .nth(1)
        .and_then(|w| w.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {buf:?}"));
    let body = match buf.split_once("\r\n\r\n") {
        Some((_, b)) => b.to_string(),
        None => String::new(),
    };
    (status, body)
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    http(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

fn delete(addr: SocketAddr, path: &str) -> (u16, String) {
    http(addr, &format!("DELETE {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str, client: &str, body: &str) -> (u16, String) {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nX-Omnivore-Client: {client}\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn parse_body(body: &str) -> Json {
    Json::parse(body).unwrap_or_else(|e| panic!("bad JSON body {body:?}: {e}"))
}

/// Poll `GET /runs/{id}` until its `state` is `want` (terminal states
/// other than `want` fail fast). Returns the final status body.
fn wait_state(addr: SocketAddr, id: &str, want: &str, timeout: Duration) -> Json {
    let deadline = Instant::now() + timeout;
    loop {
        let (status, body) = get(addr, &format!("/runs/{id}"));
        assert_eq!(status, 200, "status poll for {id}: {body}");
        let v = parse_body(&body);
        let state = v.get("state").unwrap().as_str().unwrap().to_string();
        if state == want {
            return v;
        }
        assert!(
            !matches!(state.as_str(), "done" | "failed" | "cancelled"),
            "{id} reached terminal {state:?} while waiting for {want:?}: {body}"
        );
        assert!(Instant::now() < deadline, "timed out waiting for {id} -> {want}: {body}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

// -- daemon + spec helpers ---------------------------------------------------

fn start(runs_dir: &std::path::Path, cfg: ServeConfig) -> Daemon {
    Daemon::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        runs_dir: runs_dir.to_string_lossy().into_owned(),
        ..cfg
    })
    .expect("daemon start")
}

/// A small deterministic run: 2 groups on cpu-s, 8 steps, evals firing.
fn small_spec(tag: &str) -> RunSpec {
    RunSpec::new("lenet").groups(2).steps(8).eval_every(2).seed(7).tag(tag)
}

/// A run that cannot finish before the test cancels it (tens of
/// millions of simulated iterations, evals effectively off) — how the
/// tests hold the fleet occupied deterministically.
fn hog_spec(tag: &str) -> RunSpec {
    RunSpec::new("lenet").groups(2).steps(10_000_000).eval_every(1_000_000).seed(7).tag(tag)
}

/// Zero the wall-clock-dependent fields (the only legitimate
/// difference between a daemon run and a CLI run of the same spec).
fn normalize(v: &Json) -> Json {
    let Json::Obj(map) = v else { panic!("outcome is not an object") };
    let mut map = map.clone();
    for key in ["wallclock_secs", "execute_secs", "compile_secs"] {
        assert!(map.contains_key(key), "outcome lost field {key}");
        map.insert(key.to_string(), Json::Num(0.0));
    }
    Json::Obj(map)
}

// -- tests -------------------------------------------------------------------

#[test]
fn submitted_run_matches_cli_execution_bit_for_bit() {
    let dir = omnivore::util::temp_dir("it-serve-parity").unwrap();
    let daemon = start(
        &dir,
        ServeConfig { fleet_groups: 8, workers: 2, ..ServeConfig::default() },
    );
    let addr = daemon.addr();

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert!(parse_body(&body).get("ok").unwrap().as_bool().unwrap());

    let spec = small_spec("parity");
    let (status, body) = post(addr, "/runs", "ci", &spec.to_json().dump());
    assert_eq!(status, 202, "{body}");
    let accepted = parse_body(&body);
    let id = accepted.get("id").unwrap().as_str().unwrap().to_string();
    assert_eq!(accepted.get("tag").unwrap().as_str().unwrap(), "parity");
    assert_eq!(accepted.get("state").unwrap().as_str().unwrap(), "queued");

    wait_state(addr, &id, "done", Duration::from_secs(60));

    // The event stream replays start-to-finish after the fact: eval
    // progress events from the driver plus the daemon's terminal line.
    let (status, events) = get(addr, &format!("/runs/{id}/events"));
    assert_eq!(status, 200);
    assert!(events.contains("\"kind\":\"eval\""), "no eval events in: {events}");
    let last = events.lines().last().unwrap();
    let end = parse_body(last);
    assert_eq!(end.get("kind").unwrap().as_str().unwrap(), "end");
    assert_eq!(end.get("state").unwrap().as_str().unwrap(), "done");
    assert!(end.get("stored").unwrap().as_bool().unwrap());

    // The outcome is in the same store the CLI reads, under the tag.
    let (status, body) = get(addr, "/runs/parity");
    assert_eq!(status, 200);
    assert_eq!(parse_body(&body).get("outcomes").unwrap().as_arr().unwrap().len(), 1);
    let stored = RunStore::open(&dir).unwrap().by_tag("parity").unwrap();
    assert_eq!(stored.len(), 1);

    // Bit-identity with the CLI path: same spec, same artifacts
    // resolution, fresh runtime, same execute entry point.
    let mut cli_spec = small_spec("parity");
    let art = resolve_artifacts_dir(None, Some(&cli_spec.train.artifacts_dir));
    cli_spec.train.artifacts_dir = art.clone();
    let rt = Runtime::load(&art).unwrap();
    let (init, done) = cli_spec.initial_state(&rt).unwrap();
    let (cli_outcome, _report, _params) =
        cli_spec.execute_from_step(&rt, init, done).unwrap();
    assert_eq!(
        normalize(&stored[0].to_json()).dump(),
        normalize(&cli_outcome.to_json()).dump(),
        "daemon outcome diverged from CLI outcome"
    );

    daemon.shutdown();
}

#[test]
fn admission_control_serializes_oversubscribed_runs() {
    let dir = omnivore::util::temp_dir("it-serve-queue").unwrap();
    let daemon = start(
        &dir,
        ServeConfig {
            fleet_groups: 2,
            workers: 2,
            rate: 1000.0,
            burst: 1000.0,
            max_runs_per_client: 0,
            ..ServeConfig::default()
        },
    );
    let addr = daemon.addr();

    // r1 takes the whole fleet and holds it.
    let (status, body) = post(addr, "/runs", "ci", &hog_spec("hog").to_json().dump());
    assert_eq!(status, 202, "{body}");
    let r1 = parse_body(&body).get("id").unwrap().as_str().unwrap().to_string();
    wait_state(addr, &r1, "running", Duration::from_secs(30));

    // r2's demand (2 groups) exceeds the free set (0): queued with an
    // honest position, visible in /fleet.
    let (status, body) = post(addr, "/runs", "ci", &small_spec("waiter").to_json().dump());
    assert_eq!(status, 202, "{body}");
    let acc = parse_body(&body);
    let r2 = acc.get("id").unwrap().as_str().unwrap().to_string();
    assert_eq!(acc.get("position").unwrap().as_usize().unwrap(), 1);
    let st = wait_state(addr, &r2, "queued", Duration::from_secs(5));
    assert_eq!(st.get("position").unwrap().as_usize().unwrap(), 1);
    let (_, body) = get(addr, "/fleet");
    let fleet = parse_body(&body);
    assert_eq!(fleet.get("free_groups").unwrap().as_usize().unwrap(), 0);
    assert_eq!(fleet.get("queue_depth").unwrap().as_usize().unwrap(), 1);
    assert_eq!(fleet.get("active").unwrap().as_arr().unwrap().len(), 1);

    // Cancel r1 mid-run: the driver stops cooperatively, the lease
    // returns, r2 gets the fleet and completes.
    let (status, body) = delete(addr, &format!("/runs/{r1}"));
    assert_eq!(status, 200, "{body}");
    wait_state(addr, &r1, "cancelled", Duration::from_secs(30));
    wait_state(addr, &r2, "done", Duration::from_secs(60));

    // A run cancelled mid-flight still stored its partial outcome.
    let hog = RunStore::open(&dir).unwrap().by_tag("hog").unwrap();
    assert_eq!(hog.len(), 1);
    assert!(hog[0].iters < 10_000_000, "cancelled run somehow ran to completion");

    // Zero leaked leases.
    let (_, body) = get(addr, "/fleet");
    let fleet = parse_body(&body);
    assert_eq!(fleet.get("free_groups").unwrap().as_usize().unwrap(), 2);
    assert_eq!(fleet.get("queue_depth").unwrap().as_usize().unwrap(), 0);
    assert!(fleet.get("active").unwrap().as_arr().unwrap().is_empty());

    daemon.shutdown();
}

#[test]
fn rate_limits_and_quotas_answer_429() {
    let dir = omnivore::util::temp_dir("it-serve-limits").unwrap();
    let daemon = start(
        &dir,
        ServeConfig {
            fleet_groups: 2,
            workers: 1,
            rate: 0.0, // no refill: exactly `burst` requests per client, ever
            burst: 3.0,
            max_runs_per_client: 1,
            ..ServeConfig::default()
        },
    );
    let addr = daemon.addr();

    // Token bucket: even malformed submissions spend a token; the
    // bucket (not the parser) answers once it runs dry.
    let (s1, _) = post(addr, "/runs", "alice", "not json");
    let (s2, _) = post(addr, "/runs", "alice", "not json");
    let (s3, _) = post(addr, "/runs", "alice", "not json");
    let (s4, body) = post(addr, "/runs", "alice", "not json");
    assert_eq!((s1, s2, s3), (400, 400, 400));
    assert_eq!(s4, 429, "{body}");
    assert!(body.contains("rate"), "{body}");

    // Buckets and quotas are per client: bob is unaffected by alice.
    let (status, body) = post(addr, "/runs", "bob", &hog_spec("bob-hog").to_json().dump());
    assert_eq!(status, 202, "{body}");
    let r1 = parse_body(&body).get("id").unwrap().as_str().unwrap().to_string();

    // Quota (1 concurrent run): the second submission is rejected even
    // though the request itself was well-formed and within rate.
    let (status, body) = post(addr, "/runs", "bob", &small_spec("bob-2").to_json().dump());
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("quota"), "{body}");

    // The quota seat frees when the run reaches a terminal state.
    let (status, _) = delete(addr, &format!("/runs/{r1}"));
    assert_eq!(status, 200);
    wait_state(addr, &r1, "cancelled", Duration::from_secs(30));
    let (status, body) = post(addr, "/runs", "bob", &small_spec("bob-3").to_json().dump());
    assert_eq!(status, 202, "{body}");

    daemon.shutdown();
}

#[test]
fn malformed_requests_map_to_4xx() {
    let dir = omnivore::util::temp_dir("it-serve-malformed").unwrap();
    let daemon = start(
        &dir,
        ServeConfig {
            fleet_groups: 2,
            workers: 1,
            rate: 1000.0,
            burst: 1000.0,
            ..ServeConfig::default()
        },
    );
    let addr = daemon.addr();

    // Syntactically broken request line.
    assert_eq!(http(addr, "BLARG\r\n\r\n").0, 400);
    // Well-formed but non-API method.
    assert_eq!(http(addr, "PUT /runs HTTP/1.1\r\n\r\n").0, 405);
    // Wrong method on a known path.
    assert_eq!(http(addr, "DELETE /healthz HTTP/1.1\r\n\r\n").0, 404);
    assert_eq!(post(addr, "/healthz", "x", "").0, 405);
    // Unknown paths and unknown runs.
    assert_eq!(get(addr, "/nope").0, 404);
    assert_eq!(get(addr, "/runs/r999").0, 404);
    assert_eq!(delete(addr, "/runs/not-an-id").0, 404);
    // Bodies that are not a RunSpec.
    assert_eq!(post(addr, "/runs", "x", "{").0, 400);
    assert_eq!(post(addr, "/runs", "x", "[1,2]").0, 400);
    // A demand that can never fit this fleet is rejected, not queued.
    let (status, body) =
        post(addr, "/runs", "x", &RunSpec::new("lenet").groups(4).to_json().dump());
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("never fit"), "{body}");
    // Oversized declared body: refused before allocation.
    let huge = format!(
        "POST /runs HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        16 * 1024 * 1024
    );
    assert_eq!(http(addr, &huge).0, 413);
    // Header flood: the count cap fires.
    let mut flood = String::from("GET /healthz HTTP/1.1\r\n");
    for i in 0..80 {
        flood.push_str(&format!("x-h{i}: v\r\n"));
    }
    flood.push_str("\r\n");
    assert_eq!(http(addr, &flood).0, 431);

    // The daemon is still healthy after all of that.
    assert_eq!(get(addr, "/healthz").0, 200);
    daemon.shutdown();
}
