//! Steady-state allocation discipline of the native training path.
//!
//! The scratch arena (`omnivore::backend::scratch`) counts every miss —
//! a `take()` that had to grow a fresh buffer instead of reusing a
//! cached one — behind the `invariants` feature. After a short warmup
//! (worker-pool spawn, GEMM calibration probe, first-touch growth of
//! every per-thread buffer), repeated `full_step` executions must hit
//! the arena every single time: the deterministic static partition
//! gives each worker lane the same chunks each iteration, so its
//! thread-local cache always has the right sizes on hand.
//!
//! This is its own test binary (not a module of it_backend) because the
//! counter is process-global: other tests allocating scratch would race
//! the delta assertion.

#![cfg(feature = "invariants")]

mod common;

use common::runtime;
use omnivore::backend::{scratch, Backend, NativeBackend};
use omnivore::runtime::{ArtifactEntry, TensorSpec};
use omnivore::util::rng::Rng;

#[test]
fn steady_state_full_step_never_misses_the_scratch_arena() {
    let (b, h, w, cin, c1, c2, f1, ncls, kk) =
        (4usize, 8usize, 8usize, 3usize, 4usize, 6usize, 10usize, 5usize, 3usize);
    let feat = (h / 4) * (w / 4) * c2;

    let mut rng = Rng::seed_from_u64(7);
    let mut randv = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32 * 0.1).collect() };
    let x = randv(b * h * w * cin);
    let labels: Vec<i32> = (0..b).map(|i| (i % ncls) as i32).collect();
    let params: Vec<(Vec<usize>, Vec<f32>)> = vec![
        (vec![kk, kk, cin, c1], randv(kk * kk * cin * c1)),
        (vec![c1], randv(c1)),
        (vec![kk, kk, c1, c2], randv(kk * kk * c1 * c2)),
        (vec![c2], randv(c2)),
        (vec![feat, f1], randv(feat * f1)),
        (vec![f1], randv(f1)),
        (vec![f1, ncls], randv(f1 * ncls)),
        (vec![ncls], randv(ncls)),
    ];

    let mut lits = vec![
        xla::Literal::from_f32(&[b, h, w, cin], x).unwrap(),
        xla::Literal::from_i32(&[b], labels).unwrap(),
    ];
    for (dims, data) in &params {
        lits.push(xla::Literal::from_f32(dims, data.clone()).unwrap());
    }
    let refs: Vec<&xla::Literal> = lits.iter().collect();

    let spec = |dims: &[usize]| TensorSpec { shape: dims.to_vec(), dtype: "float32".into() };
    let entry = ArtifactEntry {
        name: "alloc_probe_full_step".into(),
        file: "none".into(),
        inputs: vec![spec(&[b, h, w, cin])],
        outputs: vec![spec(&[])],
        arch: Some("tiny".into()),
        variant: Some("jnp".into()),
        kind: "full_step".into(),
        batch: Some(b),
        b_p: Some(2),
        n: None,
        gflops: None,
        lowered_bytes: None,
    };
    let rt = runtime();

    // Warmup: builds the persistent worker pool, runs the one-time GEMM
    // calibration probe, and grows every scratch buffer (main thread
    // and worker lanes) to its steady-state size.
    for _ in 0..3 {
        NativeBackend.execute(rt, &entry, &refs).unwrap();
    }

    let before = scratch::alloc_count();
    const ITERS: u64 = 5;
    for _ in 0..ITERS {
        let outs = NativeBackend.execute(rt, &entry, &refs).unwrap();
        assert_eq!(outs.len(), 10);
    }
    let after = scratch::alloc_count();
    assert_eq!(
        after - before,
        0,
        "steady-state full_step leaked {} scratch misses over {ITERS} iterations",
        after - before
    );
}
