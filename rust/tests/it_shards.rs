//! Integration/property tests for the SHARDED parameter server
//! (DESIGN.md §Perf): concurrent sharded publishes must agree with the
//! serial single-lock path up to fp reduction order, staleness
//! accounting must stay exact (S = g − 1 under round-robin groups), and
//! COW snapshots must be consistent under racing publishers.
//!
//! Everything here is xla-free, so this suite runs even without the
//! PJRT backend.

use omnivore::config::Hyper;
use omnivore::coordinator::{ModelSnapshot, ParamServer};
use omnivore::tensor::HostTensor;
use omnivore::util::prop::{arb_vec, for_all_seeds};
use omnivore::util::rng::Rng;

const SHAPES: [&[usize]; 5] = [&[64, 8], &[96], &[32, 16], &[40], &[8]];

fn init_params(rng: &mut Rng) -> Vec<HostTensor> {
    SHAPES
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            HostTensor::new(s.to_vec(), arb_vec(rng, n, 1.0)).unwrap()
        })
        .collect()
}

fn grad_set(rng: &mut Rng) -> Vec<HostTensor> {
    SHAPES
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            HostTensor::new(s.to_vec(), arb_vec(rng, n, 1.0)).unwrap()
        })
        .collect()
}

/// Concurrent sharded publishes of a commutative update (mu = lambda =
/// 0, so the final model is W0 − eta·Σg in ANY order) must match the
/// serial single-lock path up to fp reduction order.
#[test]
fn concurrent_sharded_publish_matches_serial() {
    for_all_seeds(6, 0x5a4d, |rng, seed| {
        let hyper = Hyper { lr: 0.05, momentum: 0.0, lambda: 0.0 };
        let init = init_params(rng);
        let n_threads = 4usize;
        let per_thread = 12usize;
        let grads: Vec<Vec<Vec<HostTensor>>> = (0..n_threads)
            .map(|_| (0..per_thread).map(|_| grad_set(rng)).collect())
            .collect();

        let sharded = ParamServer::with_shards(init.clone(), hyper, 4);
        std::thread::scope(|scope| {
            for thread_grads in &grads {
                let ps = &sharded;
                scope.spawn(move || {
                    for g in thread_grads {
                        let v = ps.read().version;
                        ps.publish(g, v).unwrap();
                    }
                });
            }
        });

        let serial = ParamServer::with_shards(init, hyper, 1);
        for thread_grads in &grads {
            for g in thread_grads {
                serial.publish(g, serial.version()).unwrap();
            }
        }

        let a = sharded.read();
        let b = serial.read();
        let total = (n_threads * per_thread) as u64;
        assert_eq!(a.version, total, "seed {seed:#x}: every publish counted");
        for (x, y) in a.params.iter().zip(&b.params) {
            assert_eq!(x.shape(), y.shape());
            for (xa, ya) in x.data().iter().zip(y.data()) {
                assert!(
                    (xa - ya).abs() < 1e-4,
                    "seed {seed:#x}: {xa} vs {ya} beyond fp reduction order"
                );
            }
        }
    });
}

/// Single-threaded, any shard count: the sharded server is BIT-identical
/// to the single-lock path, including with momentum and weight decay
/// (each tensor's update sequence is independent of the partition).
#[test]
fn sharded_momentum_sequence_bitwise_exact() {
    for_all_seeds(10, 0xb17, |rng, seed| {
        let hyper = Hyper { lr: 0.02, momentum: 0.85, lambda: 5e-4 };
        let init = init_params(rng);
        let steps: Vec<Vec<HostTensor>> = (0..15).map(|_| grad_set(rng)).collect();
        let reference = ParamServer::with_shards(init.clone(), hyper, 1);
        for g in &steps {
            reference.publish(g, reference.version()).unwrap();
        }
        let expect = reference.read().params;
        for shards in [2usize, 3, 5] {
            let ps = ParamServer::with_shards(init.clone(), hyper, shards);
            for g in &steps {
                ps.publish(g, ps.version()).unwrap();
            }
            for (x, y) in ps.read().params.iter().zip(&expect) {
                assert_eq!(x.data(), y.data(), "seed {seed:#x} shards {shards}");
            }
        }
    });
}

/// Round-robin groups: after the warmup ramp, every publish has
/// staleness exactly g − 1, so the mean converges to g − 1 (paper
/// §IV-A) — sharding must not perturb the accounting.
#[test]
fn round_robin_staleness_converges_to_g_minus_1() {
    for g in [1usize, 2, 4, 8] {
        let ps = ParamServer::with_shards(
            vec![HostTensor::zeros(&[16]), HostTensor::zeros(&[4])],
            Hyper { lr: 0.01, momentum: 0.9, lambda: 0.0 },
            2,
        );
        let grad = vec![HostTensor::zeros(&[16]), HostTensor::zeros(&[4])];
        let mut snaps: Vec<ModelSnapshot> = (0..g).map(|_| ps.read()).collect();
        let total = g * 25;
        for t in 0..total {
            let gi = t % g;
            let s = ps.publish(&grad, snaps[gi].version).unwrap();
            if t >= g {
                assert_eq!(s, (g - 1) as u64, "steady state staleness at t={t}");
            }
            snaps[gi] = ps.read();
        }
        let stats = ps.staleness_stats();
        assert_eq!(stats.publishes, total as u64);
        assert_eq!(stats.max_staleness, (g - 1) as u64);
        assert!(
            (stats.mean() - (g as f64 - 1.0)).abs() < 0.5,
            "g={g}: mean staleness {}",
            stats.mean()
        );
        assert_eq!(stats.histogram.iter().sum::<u64>(), total as u64);
    }
}

/// Racing readers and publishers: accounting stays exact (version ==
/// publishes, histogram sums) and every snapshot is internally
/// consistent — never a torn (partially applied) publish.
#[test]
fn concurrent_accounting_and_snapshot_consistency() {
    // Parameters engineered so a consistent model state is recognizable:
    // every publish adds exactly +1 to EVERY scalar of both tensors
    // (lr=1, grad=-1, no momentum/decay), so any untorn snapshot has all
    // scalars equal.
    let hyper = Hyper { lr: 1.0, momentum: 0.0, lambda: 0.0 };
    let params = vec![HostTensor::zeros(&[64]), HostTensor::zeros(&[48]), HostTensor::zeros(&[32])];
    let ps = ParamServer::with_shards(params, hyper, 3);
    let minus_one: Vec<HostTensor> = [64usize, 48, 32]
        .iter()
        .map(|&n| HostTensor::new(vec![n], vec![-1.0; n]).unwrap())
        .collect();
    let n_pub_threads = 4usize;
    let per_thread = 50usize;
    std::thread::scope(|scope| {
        for _ in 0..n_pub_threads {
            let ps = &ps;
            let g = &minus_one;
            scope.spawn(move || {
                for _ in 0..per_thread {
                    let v = ps.read().version;
                    ps.publish(g, v).unwrap();
                }
            });
        }
        for _ in 0..2 {
            let ps = &ps;
            scope.spawn(move || {
                for _ in 0..200 {
                    let snap = ps.read();
                    let first = snap.params[0].data()[0];
                    for t in &snap.params {
                        for &x in t.data() {
                            assert_eq!(x, first, "torn snapshot: {x} vs {first}");
                        }
                    }
                    assert_eq!(
                        first as u64, snap.version,
                        "snapshot value must equal the publishes it contains"
                    );
                }
            });
        }
    });
    let total = (n_pub_threads * per_thread) as u64;
    let stats = ps.staleness_stats();
    assert_eq!(ps.version(), total);
    assert_eq!(stats.publishes, total);
    assert_eq!(stats.histogram.iter().sum::<u64>(), total);
    let final_snap = ps.read();
    assert_eq!(final_snap.params[0].data()[0] as u64, total);
}

/// Snapshots taken while publishers race are COW-isolated: what a
/// snapshot shows never changes after the fact.
#[test]
fn snapshots_frozen_under_racing_publishes() {
    let hyper = Hyper { lr: 0.1, momentum: 0.5, lambda: 0.0 };
    let ps = ParamServer::with_shards(vec![HostTensor::zeros(&[32])], hyper, 1);
    let grad = vec![HostTensor::new(vec![32], vec![1.0; 32]).unwrap()];
    let snap = ps.read();
    let frozen: Vec<f32> = snap.params[0].data().to_vec();
    std::thread::scope(|scope| {
        let ps = &ps;
        let g = &grad;
        scope.spawn(move || {
            for _ in 0..20 {
                let v = ps.version();
                ps.publish(g, v).unwrap();
            }
        });
    });
    assert_eq!(snap.params[0].data(), &frozen[..], "snapshot mutated by publishes");
    assert_ne!(ps.read().params[0].data(), &frozen[..], "model did move");
}
