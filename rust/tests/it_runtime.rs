//! Integration: HLO-text artifacts load, compile, and execute through the
//! PJRT runtime with the shapes the manifest promises, deterministically.

mod common;

use common::runtime;
use omnivore::model::ParamSet;
use omnivore::runtime::{labels_literal, to_literal};
use omnivore::tensor::HostTensor;
use omnivore::util::rng::Rng;

fn rand_tensor(shape: &[usize], seed: u64) -> HostTensor {
    let mut rng = Rng::seed_from_u64(seed);
    HostTensor::randn(shape, 1.0, &mut rng)
}

#[test]
fn manifest_inventory_sane() {
    let m = runtime().manifest();
    assert_eq!(m.group_batch, 32);
    for arch in ["lenet", "cifar", "caffenet8"] {
        let a = m.arch(arch).unwrap();
        assert_eq!(a.params.len(), 8);
        assert_eq!(a.n_conv_params, 4);
        for variant in ["jnp", "pallas"] {
            assert_eq!(m.batches_for(arch, variant, "conv_fwd"), vec![4, 8, 16, 32]);
            assert!(m.phase_artifact(arch, variant, "fc_step", 32).is_ok());
            assert!(m.phase_artifact(arch, variant, "full_step", 32).is_ok());
            assert!(m.phase_artifact(arch, variant, "infer", 32).is_ok());
        }
    }
}

#[test]
fn infer_executes_with_promised_shapes() {
    let rt = runtime();
    let arch = rt.manifest().arch("lenet").unwrap();
    let params = ParamSet::init(arch, 0);
    let x = rand_tensor(&[32, 28, 28, 1], 1);
    let mut inputs = vec![&x];
    inputs.extend(params.tensors().iter());
    let outs = rt.execute("lenet_jnp_infer_b32", &inputs).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape(), &[32, 10]);
    assert!(outs[0].data().iter().all(|v| v.is_finite()));
}

#[test]
fn full_step_returns_finite_loss_and_grads() {
    let rt = runtime();
    let arch = rt.manifest().arch("lenet").unwrap();
    let params = ParamSet::init(arch, 0);
    let x = rand_tensor(&[32, 28, 28, 1], 2);
    let labels: Vec<i32> = (0..32).map(|i| i % 10).collect();
    let mut lits = vec![to_literal(&x).unwrap(), labels_literal(&labels).unwrap()];
    for t in params.tensors() {
        lits.push(to_literal(t).unwrap());
    }
    let outs = rt.execute_literals("lenet_jnp_full_step_b32", &lits).unwrap();
    assert_eq!(outs.len(), 2 + 8);
    let loss = omnivore::runtime::from_literal(&outs[0]).unwrap().scalar().unwrap();
    let acc = omnivore::runtime::from_literal(&outs[1]).unwrap().scalar().unwrap();
    // Fresh init, 10 classes: loss ~ ln(10), acc ~ 10%.
    assert!((loss - 10f32.ln()).abs() < 0.2, "loss {loss}");
    assert!((0.0..=1.0).contains(&acc));
    for (o, p) in outs[2..].iter().zip(params.tensors()) {
        let g = omnivore::runtime::from_literal(o).unwrap();
        assert_eq!(g.shape(), p.shape());
        assert!(g.data().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn execution_is_deterministic() {
    let rt = runtime();
    let arch = rt.manifest().arch("lenet").unwrap();
    let params = ParamSet::init(arch, 3);
    let x = rand_tensor(&[32, 28, 28, 1], 4);
    let mut inputs = vec![&x];
    inputs.extend(params.tensors().iter());
    let a = rt.execute("lenet_jnp_infer_b32", &inputs).unwrap();
    let b = rt.execute("lenet_jnp_infer_b32", &inputs).unwrap();
    assert_eq!(a[0], b[0]);
}

#[test]
fn conv_fwd_microbatch_composition() {
    // conv_fwd(b=8) == concat(conv_fwd(b=4) x 2): the artifact family is
    // batch-consistent, which Topology's microbatching relies on.
    let rt = runtime();
    let arch = rt.manifest().arch("lenet").unwrap();
    let params = ParamSet::init(arch, 5);
    let x = rand_tensor(&[8, 28, 28, 1], 6);
    let mut inputs = vec![&x];
    inputs.extend(params.conv().iter());
    let whole = rt.execute("lenet_jnp_conv_fwd_b8", &inputs).unwrap();
    let halves = x.split0(2).unwrap();
    let mut parts = vec![];
    for h in &halves {
        let mut inp = vec![h];
        inp.extend(params.conv().iter());
        parts.push(rt.execute("lenet_jnp_conv_fwd_b4", &inp).unwrap().remove(0));
    }
    let cat = HostTensor::concat0(&parts).unwrap();
    assert_eq!(cat.shape(), whole[0].shape());
    for (a, b) in cat.data().iter().zip(whole[0].data()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn pallas_and_jnp_variants_agree() {
    let rt = runtime();
    let arch = rt.manifest().arch("lenet").unwrap();
    let params = ParamSet::init(arch, 7);
    let x = rand_tensor(&[32, 28, 28, 1], 8);
    let mut inputs = vec![&x];
    inputs.extend(params.tensors().iter());
    let a = rt.execute("lenet_jnp_infer_b32", &inputs).unwrap();
    let b = rt.execute("lenet_pallas_infer_b32", &inputs).unwrap();
    for (x, y) in a[0].data().iter().zip(b[0].data()) {
        assert!((x - y).abs() < 2e-3, "{x} vs {y}");
    }
}

#[test]
fn unknown_artifact_errors_cleanly() {
    let rt = runtime();
    assert!(rt.execute("does_not_exist", &[]).is_err());
}

#[test]
fn compile_cache_reused() {
    // Compilation is the stub/PJRT path's concern: it needs the HLO text
    // on disk. The native backend executes from the manifest alone, so a
    // checkout without generated artifacts skips this one.
    let rt = runtime();
    let entry = rt.manifest().entry("lenet_jnp_infer_b32").unwrap();
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join(&entry.file).exists() {
        eprintln!("skipping compile_cache_reused: run `make artifacts` to emit HLO text");
        return;
    }
    rt.compile("lenet_jnp_infer_b32").unwrap();
    let before = rt.stats().compile_secs;
    rt.compile("lenet_jnp_infer_b32").unwrap();
    let after = rt.stats().compile_secs;
    assert_eq!(before, after, "second compile must hit the cache");
}
