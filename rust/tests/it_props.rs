//! Property tests (seeded, replayable — util::prop) over coordinator
//! invariants: the parameter server's accounting, the HE model's
//! structure, the FLOPS partitioner, and dataset determinism.

mod common;

use omnivore::baselines::flops_proportional_split;
use omnivore::config::Hyper;
use omnivore::coordinator::ParamServer;
use omnivore::data::SyntheticDataset;
use omnivore::optimizer::se_model;
use omnivore::optimizer::HeParams;
use omnivore::tensor::HostTensor;
use omnivore::util::prop::{arb_vec, for_all_seeds};

#[test]
fn param_server_accounting_any_interleaving() {
    // Under arbitrary read/publish interleavings: version == publishes,
    // staleness histogram sums to publishes, staleness <= outstanding.
    for_all_seeds(30, 0xabc, |rng, _seed| {
        let ps = ParamServer::new(
            vec![HostTensor::zeros(&[8])],
            Hyper { lr: 0.01, momentum: 0.5, lambda: 0.0 },
        );
        let mut outstanding = vec![];
        let mut publishes = 0u64;
        for _ in 0..60 {
            if rng.bool() || outstanding.is_empty() {
                outstanding.push(ps.read());
            } else {
                let snap = outstanding.remove(rng.below(outstanding.len()));
                let g = vec![HostTensor::new(vec![8], arb_vec(rng, 8, 1.0)).unwrap()];
                let s = ps.publish(&g, snap.version).unwrap();
                publishes += 1;
                assert!(s <= 60, "staleness bounded by total ops");
            }
        }
        let stats = ps.staleness_stats();
        assert_eq!(stats.publishes, publishes);
        assert_eq!(ps.version(), publishes);
        assert_eq!(stats.histogram.iter().sum::<u64>(), publishes);
        assert!(stats.max_staleness as f64 >= stats.mean());
    });
}

#[test]
fn sgd_with_zero_lr_never_moves() {
    for_all_seeds(10, 0xdef, |rng, _| {
        let w0 = arb_vec(rng, 16, 2.0);
        let ps = ParamServer::new(
            vec![HostTensor::new(vec![16], w0.clone()).unwrap()],
            Hyper { lr: 0.0, momentum: 0.9, lambda: 0.0 },
        );
        for _ in 0..5 {
            let g = vec![HostTensor::new(vec![16], arb_vec(rng, 16, 1.0)).unwrap()];
            ps.publish(&g, ps.version()).unwrap();
        }
        assert_eq!(ps.read().params[0].data(), &w0[..]);
    });
}

#[test]
fn he_model_structural_invariants() {
    for_all_seeds(40, 0x11e, |rng, seed| {
        let he = HeParams::measured(
            0.01 + rng.f64() * 10.0,
            rng.f64() * 0.1,
            0.001 + rng.f64(),
        );
        let n = 1 << (1 + rng.below(6)); // 2..64
        let mut prev = f64::INFINITY;
        let mut g = 1;
        while g <= n {
            let t = he.iteration_time(g, n);
            assert!(t > 0.0);
            assert!(
                t <= prev + 1e-12,
                "seed {seed:#x}: HE must be non-increasing in g (n={n}, g={g})"
            );
            // Saturated => iteration time is exactly t_fc.
            if he.fc_saturated(g, n) {
                assert!((t - he.t_fc).abs() < 1e-12);
            }
            prev = t;
            g *= 2;
        }
        // The short-circuit start always saturates (or falls back to n).
        let g0 = he.smallest_saturating_g(n);
        assert!(g0 <= n);
        if g0 < n {
            assert!(he.fc_saturated(g0, n));
        }
    });
}

#[test]
fn implicit_momentum_monotone_and_bounded() {
    for g in 1..=64 {
        let m = se_model::implicit_momentum(g);
        assert!((0.0..1.0).contains(&m));
        if g > 1 {
            assert!(m > se_model::implicit_momentum(g - 1));
        }
        // compensation inverts composition exactly when feasible
        let target = 0.95;
        let mu = se_model::compensated_momentum(target, g);
        if mu > 0.0 {
            let total = 1.0 - (1.0 - m) * (1.0 - mu);
            assert!((total - target).abs() < 1e-9, "g={g}");
        }
    }
}

#[test]
fn flops_split_properties() {
    for_all_seeds(40, 0xf10, |rng, seed| {
        let n_dev = 1 + rng.below(5);
        let tflops: Vec<f64> = (0..n_dev).map(|_| 0.1 + rng.f64() * 10.0).collect();
        let batch = 1 + rng.below(512);
        let split = flops_proportional_split(batch, &tflops);
        assert_eq!(split.len(), n_dev);
        assert_eq!(split.iter().sum::<usize>(), batch, "seed {seed:#x}");
        // Each share within 1 image + proportional bound.
        let total: f64 = tflops.iter().sum();
        for (s, t) in split.iter().zip(&tflops) {
            let ideal = batch as f64 * t / total;
            assert!(
                (*s as f64 - ideal).abs() <= n_dev as f64,
                "seed {seed:#x}: share {s} vs ideal {ideal}"
            );
        }
    });
}

#[test]
fn dataset_batches_deterministic_and_labeled() {
    for_all_seeds(10, 0xda7, |rng, _| {
        let seed = rng.next_u64();
        let ds = SyntheticDataset::for_arch("cifar", seed);
        let idx = rng.next_u64() % 1000;
        let a = ds.batch(idx, 16);
        let b = ds.batch(idx, 16);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        assert!(a.labels.iter().all(|&l| (0..10).contains(&l)));
        assert_eq!(a.images.shape(), &[16, 32, 32, 3]);
    });
}

#[test]
fn ar1_fit_recovers_momentum_under_noise() {
    for_all_seeds(20, 0xa21, |rng, seed| {
        let mu = 0.1 + 0.8 * rng.f64();
        let mut x = 0.0;
        let mut v = 0.5;
        let mut xs = vec![x];
        for _ in 0..400 {
            v = mu * v - 0.01 + 0.0005 * rng.normal();
            x += v;
            xs.push(x);
        }
        let fit = omnivore::optimizer::se_model::fit_ar1(&xs).unwrap();
        assert!(
            (fit - mu).abs() < 0.1,
            "seed {seed:#x}: fit {fit:.3} vs true {mu:.3}"
        );
    });
}
