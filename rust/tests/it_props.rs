//! Property tests (seeded, replayable — util::prop) over coordinator
//! invariants: the parameter server's accounting, the HE model's
//! structure (homogeneous and profile-aware), the FLOPS partitioner and
//! batch plan, and dataset determinism.

mod common;

use std::sync::Arc;

use omnivore::baselines::flops_proportional_split;
use omnivore::config::{
    cluster, DeviceKind, DeviceProfile, FaultEvent, FaultSchedule, Hyper, ProfileDrift,
    FAULT_VERSION,
};
use omnivore::coordinator::ParamServer;
use omnivore::data::{AdaptivePolicy, BatchPlan, PlanController, SyntheticDataset};
use omnivore::optimizer::se_model;
use omnivore::optimizer::{HeParams, ProfiledHe};
use omnivore::sim::{ClusterSim, ServiceDist, TimingModel};
use omnivore::tensor::HostTensor;
use omnivore::util::json::Json;
use omnivore::util::prop::{arb_vec, for_all_seeds};

#[test]
fn param_server_accounting_any_interleaving() {
    // Under arbitrary read/publish interleavings: version == publishes,
    // staleness histogram sums to publishes, staleness <= outstanding.
    for_all_seeds(30, 0xabc, |rng, _seed| {
        let ps = ParamServer::new(
            vec![HostTensor::zeros(&[8])],
            Hyper { lr: 0.01, momentum: 0.5, lambda: 0.0 },
        );
        let mut outstanding = vec![];
        let mut publishes = 0u64;
        for _ in 0..60 {
            if rng.bool() || outstanding.is_empty() {
                outstanding.push(ps.read());
            } else {
                let snap = outstanding.remove(rng.below(outstanding.len()));
                let g = vec![HostTensor::new(vec![8], arb_vec(rng, 8, 1.0)).unwrap()];
                let s = ps.publish(&g, snap.version).unwrap();
                publishes += 1;
                assert!(s <= 60, "staleness bounded by total ops");
            }
        }
        let stats = ps.staleness_stats();
        assert_eq!(stats.publishes, publishes);
        assert_eq!(ps.version(), publishes);
        assert_eq!(stats.histogram.iter().sum::<u64>(), publishes);
        assert!(stats.max_staleness as f64 >= stats.mean());
    });
}

#[test]
fn sgd_with_zero_lr_never_moves() {
    for_all_seeds(10, 0xdef, |rng, _| {
        let w0 = arb_vec(rng, 16, 2.0);
        let ps = ParamServer::new(
            vec![HostTensor::new(vec![16], w0.clone()).unwrap()],
            Hyper { lr: 0.0, momentum: 0.9, lambda: 0.0 },
        );
        for _ in 0..5 {
            let g = vec![HostTensor::new(vec![16], arb_vec(rng, 16, 1.0)).unwrap()];
            ps.publish(&g, ps.version()).unwrap();
        }
        assert_eq!(ps.read().params[0].data(), &w0[..]);
    });
}

#[test]
fn he_model_structural_invariants() {
    for_all_seeds(40, 0x11e, |rng, seed| {
        let he = HeParams::measured(
            0.01 + rng.f64() * 10.0,
            rng.f64() * 0.1,
            0.001 + rng.f64(),
        );
        let n = 1 << (1 + rng.below(6)); // 2..64
        let mut prev = f64::INFINITY;
        let mut g = 1;
        while g <= n {
            let t = he.iteration_time(g, n);
            assert!(t > 0.0);
            assert!(
                t <= prev + 1e-12,
                "seed {seed:#x}: HE must be non-increasing in g (n={n}, g={g})"
            );
            // Saturated => iteration time is exactly t_fc.
            if he.fc_saturated(g, n) {
                assert!((t - he.t_fc).abs() < 1e-12);
            }
            prev = t;
            g *= 2;
        }
        // The short-circuit start always saturates (or falls back to n).
        let g0 = he.smallest_saturating_g(n);
        assert!(g0 <= n);
        if g0 < n {
            assert!(he.fc_saturated(g0, n));
        }
    });
}

#[test]
fn implicit_momentum_monotone_and_bounded() {
    for g in 1..=64 {
        let m = se_model::implicit_momentum(g);
        assert!((0.0..1.0).contains(&m));
        if g > 1 {
            assert!(m > se_model::implicit_momentum(g - 1));
        }
        // compensation inverts composition exactly when feasible
        let target = 0.95;
        let mu = se_model::compensated_momentum(target, g);
        if mu > 0.0 {
            let total = 1.0 - (1.0 - m) * (1.0 - mu);
            assert!((total - target).abs() < 1e-9, "g={g}");
        }
    }
}

#[test]
fn flops_split_properties() {
    for_all_seeds(40, 0xf10, |rng, seed| {
        let n_dev = 1 + rng.below(5);
        let tflops: Vec<f64> = (0..n_dev).map(|_| 0.1 + rng.f64() * 10.0).collect();
        let batch = 1 + rng.below(512);
        let split = flops_proportional_split(batch, &tflops);
        assert_eq!(split.len(), n_dev);
        assert_eq!(split.iter().sum::<usize>(), batch, "seed {seed:#x}");
        // Each share within 1 image + proportional bound.
        let total: f64 = tflops.iter().sum();
        for (s, t) in split.iter().zip(&tflops) {
            let ideal = batch as f64 * t / total;
            assert!(
                (*s as f64 - ideal).abs() <= n_dev as f64,
                "seed {seed:#x}: share {s} vs ideal {ideal}"
            );
        }
    });
}

#[test]
fn flops_split_degenerate_inputs() {
    // Satellite regression: empty device lists, zero/negative totals,
    // and non-finite entries must yield one share per device (summing
    // to batch) instead of a wrong-length vector or a usize underflow.
    assert_eq!(flops_proportional_split(100, &[]), Vec::<usize>::new());
    for_all_seeds(30, 0xf11, |rng, seed| {
        let n_dev = 1 + rng.below(6);
        let tflops: Vec<f64> = (0..n_dev)
            .map(|_| match rng.below(4) {
                0 => -rng.f64() * 5.0,
                1 => 0.0,
                2 => f64::NAN,
                _ => 0.1 + rng.f64() * 10.0,
            })
            .collect();
        let batch = rng.below(512);
        let split = flops_proportional_split(batch, &tflops);
        assert_eq!(split.len(), n_dev, "seed {seed:#x}: one share per device");
        assert_eq!(split.iter().sum::<usize>(), batch, "seed {seed:#x}");
        // A clamped-to-zero device never out-claims a positive one.
        if let Some(max_pos) = tflops
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_finite() && **t > 0.0)
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
        {
            for (i, t) in tflops.iter().enumerate() {
                if !(t.is_finite() && *t > 0.0) {
                    assert!(
                        split[i] <= split[max_pos],
                        "seed {seed:#x}: dead device {i} got {} > {}",
                        split[i],
                        split[max_pos]
                    );
                }
            }
        }
    });
}

#[test]
fn batch_plan_properties() {
    // Shares sum to the batch, are deterministic, monotone in profile
    // speed, and reduce to the equal split on baseline profiles.
    for_all_seeds(40, 0xb47, |rng, seed| {
        let groups = 1 + rng.below(8);
        let batch = groups + rng.below(256);
        let speeds: Vec<f64> = (0..groups).map(|_| 0.25 + rng.f64() * 8.0).collect();
        let plan = BatchPlan::proportional(batch, &speeds);
        let again = BatchPlan::proportional(batch, &speeds);
        assert_eq!(plan, again, "seed {seed:#x}: deterministic");
        assert_eq!(plan.shares().iter().sum::<usize>(), batch, "seed {seed:#x}");
        assert_eq!(plan.groups(), groups);
        // Floor: every group computes at least one image, so no group
        // ever runs with work fraction / gradient weight 0.
        assert!(plan.shares().iter().all(|&s| s >= 1), "seed {seed:#x}: {:?}", plan.shares());
        // Monotone: a strictly faster group never gets a smaller share.
        for i in 0..groups {
            for j in 0..groups {
                if speeds[i] > speeds[j] {
                    assert!(
                        plan.share(i) >= plan.share(j),
                        "seed {seed:#x}: speed {} got {} < speed {} with {}",
                        speeds[i],
                        plan.share(i),
                        speeds[j],
                        plan.share(j)
                    );
                }
            }
        }
        // Gradient weights sum to g (unbiased full-batch round).
        let wsum: f64 = (0..groups).map(|g| plan.work_fraction(g)).sum();
        assert!((wsum - groups as f64).abs() < 1e-9, "seed {seed:#x}: {wsum}");
        // Baseline (uniform) speeds reduce to the equal split's shares.
        let uniform = BatchPlan::proportional(batch, &vec![1.0; groups]);
        let equal = BatchPlan::equal(batch, groups);
        assert_eq!(
            uniform.shares().iter().sum::<usize>(),
            equal.shares().iter().sum::<usize>()
        );
        let (min_u, max_u) = (
            uniform.shares().iter().min().unwrap(),
            uniform.shares().iter().max().unwrap(),
        );
        assert!(max_u - min_u <= 1, "seed {seed:#x}: uniform speeds near-equal split");
    });
}

#[test]
fn plan_controller_epoch_invariants_any_swap_schedule() {
    // Under ARBITRARY observation streams (random gaps, random replan
    // attempts): versions stay dense and monotone, every epoch's shares
    // sum to the batch, every share is >= 1, within each epoch the g
    // gradient weights sum to g, and weights resolve by version across
    // any swap (so a publish bound to epoch k is weighted by epoch k
    // forever).
    for_all_seeds(30, 0xada, |rng, seed| {
        let groups = 2 + rng.below(6);
        let batch = groups * (1 + rng.below(16)) + rng.below(groups);
        let policy = AdaptivePolicy {
            min_observations: 1 + rng.below(4) as u64,
            min_interval: rng.f64() * 2.0,
            ..Default::default()
        };
        let c = PlanController::adaptive(BatchPlan::equal(batch, groups), policy);
        let mut vtime = 0.0;
        for _ in 0..200 {
            vtime += rng.f64();
            let g = rng.below(groups);
            // Occasionally degenerate observations, which must be ignored.
            let gap = match rng.below(8) {
                0 => f64::NAN,
                1 => -1.0,
                _ => 0.1 + rng.f64() * (1.0 + 4.0 * ((g % 3) as f64)),
            };
            c.observe(g, gap);
            c.maybe_replan(vtime);
        }
        let epochs = c.epochs();
        for (i, e) in epochs.iter().enumerate() {
            assert_eq!(e.version, i as u64, "seed {seed:#x}: dense monotone versions");
            assert_eq!(
                e.plan.shares().iter().sum::<usize>(),
                batch,
                "seed {seed:#x}: epoch {i} shares {:?}",
                e.plan.shares()
            );
            assert!(
                e.plan.shares().iter().all(|&s| s >= 1),
                "seed {seed:#x}: zero share in epoch {i}"
            );
            let wsum: f64 = (0..groups).map(|g| e.plan.grad_weight(g) as f64).sum();
            assert!(
                (wsum - groups as f64).abs() < 1e-4,
                "seed {seed:#x}: epoch {i} weights sum {wsum} != {groups}"
            );
            // Version-resolved lookup returns THIS epoch's weight.
            for g in 0..groups {
                assert_eq!(c.grad_weight(e.version, g), e.plan.grad_weight(g));
            }
        }
        // Epoch onset times never decrease.
        for w in epochs.windows(2) {
            assert!(w[0].since_vtime <= w[1].since_vtime, "seed {seed:#x}");
        }
    });
}

#[test]
fn adaptive_replanning_recovers_drift_stall_in_timing_sim() {
    // Pure-timing acceptance twin of the engine test: a declared-
    // homogeneous cluster where group 0 throttles 3x mid-run. The
    // static equal plan pays the full straggler stall forever; a
    // planner-backed timing model re-partitions from measured cadence
    // and cuts the measured stall by well over the required 30%.
    let he = HeParams::measured(1.0, 0.002, 0.01);
    let profiles = vec![
        DeviceProfile::baseline(DeviceKind::Cpu)
            .with_drift(ProfileDrift::Step { at: 30.0, factor: 1.0 / 3.0 }),
        DeviceProfile::baseline(DeviceKind::Cpu),
        DeviceProfile::baseline(DeviceKind::Cpu),
        DeviceProfile::baseline(DeviceKind::Cpu),
    ];
    let (n, g, iters) = (8, 4, 4000u64);
    let stat = ClusterSim::new(
        TimingModel::with_profiles(he, ServiceDist::Deterministic, profiles.clone()),
        n,
    )
    .run(g, iters, 1);
    let planner = Arc::new(PlanController::adaptive(
        BatchPlan::equal(32, g),
        AdaptivePolicy::default(),
    ));
    let adap = ClusterSim::new(
        TimingModel::with_planner(
            he,
            ServiceDist::Deterministic,
            profiles,
            planner.clone(),
        ),
        n,
    )
    .run(g, iters, 1);
    // Both runs complete all iterations; stalls compare group mean
    // cycles (conv + fc, no queue wait), which the plan directly scales.
    assert!(stat.straggler_stall() > 0.5, "static stall {}", stat.straggler_stall());
    assert!(
        adap.straggler_stall() < 0.7 * stat.straggler_stall(),
        "adaptive stall {} vs static {}: < 30% cut required",
        adap.straggler_stall(),
        stat.straggler_stall()
    );
    // The re-plan actually happened, with coherent epochs.
    let epochs = planner.epochs();
    assert!(epochs.len() >= 2, "no adaptive epoch published");
    for e in &epochs {
        assert_eq!(e.plan.shares().iter().sum::<usize>(), 32);
    }
    let last = epochs.last().unwrap();
    assert!(
        last.plan.share(0) < last.plan.share(1),
        "throttled group must shed work: {:?}",
        last.plan.shares()
    );
}

#[test]
fn fc_queue_wait_pins_cluster_sim_measurement() {
    // The M/G/1-style finite-population wait must land in the same
    // regime the discrete-event simulator measures at the shared FC
    // server (exponential service, Theorem 1's assumption), where the
    // queue-free model predicts exactly zero. Tolerance is generous —
    // the sim's conv barrier and closed-loop arrivals are only
    // approximately the model's exponential think time — but the
    // prediction must be non-trivially positive and the right size.
    for (t_fc, n, g) in [(0.08, 4, 4), (0.15, 2, 2)] {
        let he = HeParams::measured(1.0, 0.0, t_fc);
        let phe = ProfiledHe::homogeneous(he);
        let predicted = phe.fc_queue_wait(g, n);
        assert!(predicted > 0.0);
        let sim = ClusterSim::new(TimingModel::new(he, ServiceDist::Exponential), n);
        let measured = sim.run(g, 20_000, 11).fc_wait_mean;
        assert!(
            measured > 0.0,
            "t_fc={t_fc} g={g}: simulator shows no FC wait?"
        );
        let ratio = predicted / measured;
        assert!(
            (0.3..3.0).contains(&ratio),
            "t_fc={t_fc} g={g}: predicted {predicted} vs measured {measured} (x{ratio:.2})"
        );
        // The queued iteration-time prediction is closer to the
        // measured mean than the queue-free cliff form.
        let m = sim.run(g, 20_000, 12).mean_iter_time;
        let free_err = (phe.iteration_time(g, n) - m).abs();
        let queued_err = (phe.iteration_time_queued(g, n) - m).abs();
        // Small absolute slack: the two predictions differ by ~1% of
        // the iteration time here, of the same order as the closed-loop
        // effects the approximation ignores.
        assert!(
            queued_err <= free_err + 0.002,
            "t_fc={t_fc} g={g}: queued {} vs free {} against measured {m}",
            phe.iteration_time_queued(g, n),
            phe.iteration_time(g, n)
        );
    }
}

/// Acceptance: on the `hetero-s` and `straggler-s` presets with
/// deterministic service times, the profile-aware `iteration_time(g, n)`
/// matches the discrete-event cluster measurement within 5% for
/// g in {1, 2, 4} — equal split and FLOPS-proportional shares alike.
#[test]
fn profiled_he_matches_cluster_sim_on_hetero_presets() {
    // Conv-bound parameters (FC utilization < ~30% at every point
    // tested): the model deliberately omits the FC queueing wait, which
    // the paper also accepts ("almost exact" in saturation,
    // under-estimates when queueing matters).
    let he = HeParams::measured(1.0, 0.002, 0.01);
    for name in ["hetero-s", "straggler-s"] {
        let cl = cluster::preset(name).unwrap();
        let n = cl.machines - 1;
        for dynamic in [false, true] {
            let phe =
                he.with_profiles(cl.group_profiles.clone(), 32).with_dynamic_batch(dynamic);
            for g in [1usize, 2, 4] {
                let timing = TimingModel::with_plan(
                    he,
                    ServiceDist::Deterministic,
                    cl.group_profiles.clone(),
                    phe.work_fractions(g),
                );
                let measured =
                    ClusterSim::new(timing, n).run(g, 4000, 0).mean_iter_time;
                let predicted = phe.iteration_time(g, n);
                let err = (measured / predicted - 1.0).abs();
                assert!(
                    err < 0.05,
                    "{name} dynamic={dynamic} g={g}: predicted {predicted} \
                     measured {measured} ({:.1}% off)",
                    err * 100.0
                );
            }
        }
    }
}

#[test]
fn profiled_he_homogeneous_reduction_any_params() {
    // With no profiles the profile-aware model must agree with the
    // closed-form HeParams everywhere (iteration time, saturation, and
    // the short-circuit g).
    for_all_seeds(30, 0x9e7, |rng, seed| {
        let he = HeParams::measured(
            0.01 + rng.f64() * 10.0,
            rng.f64() * 0.1,
            0.001 + rng.f64(),
        );
        let phe = ProfiledHe::homogeneous(he);
        let n = 1 << (1 + rng.below(6));
        let mut g = 1;
        while g <= n {
            let a = he.iteration_time(g, n);
            let b = phe.iteration_time(g, n);
            assert!(
                (a - b).abs() <= a * 1e-9,
                "seed {seed:#x}: n={n} g={g}: {a} vs {b}"
            );
            assert_eq!(he.fc_saturated(g, n), phe.fc_saturated(g, n), "seed {seed:#x}");
            g *= 2;
        }
        assert_eq!(
            he.smallest_saturating_g(n),
            phe.smallest_saturating_g(n),
            "seed {seed:#x}"
        );
    });
}

#[test]
fn dynamic_shares_cut_straggler_stall_on_presets() {
    // The fig20 hetero acceptance: FLOPS-proportional shares reduce the
    // straggler group's per-iteration idle/barrier gap vs the equal
    // split on both heterogeneous presets.
    let he = HeParams::measured(1.0, 0.002, 0.01);
    for name in ["hetero-s", "straggler-s"] {
        let cl = cluster::preset(name).unwrap();
        let n = cl.machines - 1;
        let phe = he.with_profiles(cl.group_profiles.clone(), 32).with_dynamic_batch(true);
        for g in [2usize, 4] {
            let run = |work: Vec<f64>| {
                let timing = TimingModel::with_plan(
                    he,
                    ServiceDist::Deterministic,
                    cl.group_profiles.clone(),
                    work,
                );
                ClusterSim::new(timing, n).run(g, 2000, 1)
            };
            let equal = run(vec![1.0; g]);
            let dynamic = run(phe.work_fractions(g));
            assert!(
                equal.straggler_stall() > 0.0,
                "{name} g={g}: equal split shows no imbalance?"
            );
            assert!(
                dynamic.straggler_stall() < equal.straggler_stall() * 0.6,
                "{name} g={g}: dynamic stall {} vs equal {}",
                dynamic.straggler_stall(),
                equal.straggler_stall()
            );
        }
    }
}

#[test]
fn fault_schedule_constructor_and_parser_agree_on_any_candidate() {
    // Random candidate event sets (valid and invalid alike): the
    // validating constructor and the versioned JSON parser must accept
    // exactly the same sets, and every accepted schedule must survive a
    // dump/parse round-trip bit-for-bit.
    for_all_seeds(60, 0xfa117, |rng, seed| {
        let n_ev = rng.below(6);
        let mut events = Vec::with_capacity(n_ev);
        for _ in 0..n_ev {
            let group = rng.below(3);
            // Quarter-second grid: exact in f64 and in the JSON dump.
            let t = rng.below(40) as f64 * 0.25;
            let span = 0.25 + rng.below(12) as f64 * 0.25;
            events.push(match rng.below(4) {
                0 => FaultEvent::Crash { group, at: t },
                1 => FaultEvent::Restart { group, at: t },
                2 => FaultEvent::Stall { group, from: t, to: t + span },
                _ => FaultEvent::FcPartition { from: t, to: t + span },
            });
        }
        let constructed = FaultSchedule::new(events.clone());
        // Hand-assemble the file a user would write for these events.
        let dumped = Json::obj(vec![
            ("fault_version", Json::Num(FAULT_VERSION as f64)),
            ("events", Json::Arr(events.iter().map(|e| e.to_json()).collect())),
        ])
        .dump();
        let parsed = FaultSchedule::from_json(&Json::parse(&dumped).unwrap());
        match constructed {
            Ok(f) => {
                let p = parsed.unwrap_or_else(|e| panic!("seed {seed:#x}: {e}"));
                assert_eq!(f, p, "seed {seed:#x}: parse != construct");
                let back =
                    FaultSchedule::from_json(&Json::parse(&f.to_json().dump()).unwrap())
                        .unwrap();
                assert_eq!(f, back, "seed {seed:#x}: dump/parse round-trip");
            }
            Err(_) => assert!(
                parsed.is_err(),
                "seed {seed:#x}: parser accepted an event set the constructor rejects"
            ),
        }
    });
}

#[test]
fn param_server_fence_drops_are_structural_noops() {
    // Property twin of the engine's gradient fencing: a publish carrying
    // a plan version below its group's fence is dropped and counted,
    // leaving parameters, version, and staleness accounting bit-identical
    // to a server that never saw it. Unfenced groups pass regardless.
    for_all_seeds(30, 0xfe9ce, |rng, seed| {
        let mk = || {
            ParamServer::new(
                vec![HostTensor::zeros(&[8])],
                Hyper { lr: 0.05, momentum: 0.7, lambda: 0.0 },
            )
        };
        let (fenced, clean) = (mk(), mk());
        let fence_at = 1 + rng.below(4) as u64;
        fenced.raise_fence(0, fence_at);
        let mut dropped = 0u64;
        for _ in 0..40 {
            let g = vec![HostTensor::new(vec![8], arb_vec(rng, 8, 1.0)).unwrap()];
            let pv = rng.below(8) as u64;
            let s = fenced
                .publish_scaled_fenced(&g, fenced.version(), 1.0, 0, pv)
                .unwrap();
            if pv < fence_at {
                assert!(s.is_none(), "seed {seed:#x}: fenced publish applied");
                dropped += 1;
            } else {
                assert!(s.is_some(), "seed {seed:#x}: unfenced publish dropped");
                clean
                    .publish_scaled_fenced(&g, clean.version(), 1.0, 0, pv)
                    .unwrap();
            }
        }
        // Force at least one drop and one cross-group pass-through.
        let g = vec![HostTensor::new(vec![8], arb_vec(rng, 8, 1.0)).unwrap()];
        assert!(fenced
            .publish_scaled_fenced(&g, fenced.version(), 1.0, 0, 0)
            .unwrap()
            .is_none());
        dropped += 1;
        for ps in [&fenced, &clean] {
            assert!(
                ps.publish_scaled_fenced(&g, ps.version(), 1.0, 1, 0).unwrap().is_some(),
                "seed {seed:#x}: fence on group 0 must not block group 1"
            );
        }
        assert_eq!(fenced.dropped_stale(), dropped, "seed {seed:#x}");
        assert_eq!(clean.dropped_stale(), 0, "seed {seed:#x}");
        assert_eq!(fenced.version(), clean.version(), "seed {seed:#x}: version skew");
        assert_eq!(
            fenced.read().params[0].data(),
            clean.read().params[0].data(),
            "seed {seed:#x}: fenced drops must not move parameters"
        );
        let (a, b) = (fenced.staleness_stats(), clean.staleness_stats());
        assert_eq!(a.publishes, b.publishes, "seed {seed:#x}: drops counted as publishes");
    });
}

#[test]
fn dataset_batches_deterministic_and_labeled() {
    for_all_seeds(10, 0xda7, |rng, _| {
        let seed = rng.next_u64();
        let ds = SyntheticDataset::for_arch("cifar", seed);
        let idx = rng.next_u64() % 1000;
        let a = ds.batch(idx, 16);
        let b = ds.batch(idx, 16);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        assert!(a.labels.iter().all(|&l| (0..10).contains(&l)));
        assert_eq!(a.images.shape(), &[16, 32, 32, 3]);
    });
}

#[test]
fn ar1_fit_recovers_momentum_under_noise() {
    for_all_seeds(20, 0xa21, |rng, seed| {
        let mu = 0.1 + 0.8 * rng.f64();
        let mut x = 0.0;
        let mut v = 0.5;
        let mut xs = vec![x];
        for _ in 0..400 {
            v = mu * v - 0.01 + 0.0005 * rng.normal();
            x += v;
            xs.push(x);
        }
        let fit = omnivore::optimizer::se_model::fit_ar1(&xs).unwrap();
        assert!(
            (fit - mu).abs() < 0.1,
            "seed {seed:#x}: fit {fit:.3} vs true {mu:.3}"
        );
    });
}
