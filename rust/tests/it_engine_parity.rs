//! Integration: engine parity through the unified driver — all three
//! schedulers run the same `TrainSession` core, so degenerate
//! configurations must agree across them, `EngineOptions` must be
//! honored everywhere, and heterogeneous device profiles must show up
//! in the per-group report.

mod common;

use common::runtime;
use omnivore::config::{cluster, FaultSchedule, Hyper, Strategy, TrainConfig};
use omnivore::data::SyntheticDataset;
use omnivore::engine::{
    AveragingEngine, EngineOptions, SchedulerKind, SimTimeEngine, ThreadedEngine,
};
use omnivore::model::ParamSet;
use omnivore::optimizer::HeParams;
use omnivore::runtime::{from_literal, labels_literal, to_literal};
use omnivore::sim::ServiceDist;
use omnivore::tensor::{momentum_sgd_step, HostTensor};

fn cfg(groups: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        arch: "lenet".into(),
        variant: "jnp".into(),
        cluster: cluster::preset("cpu-s").unwrap(),
        strategy: Strategy::Groups(groups),
        hyper: Hyper { lr: 0.03, momentum: 0.6, lambda: 5e-4 },
        steps,
        seed: 0,
        ..TrainConfig::default()
    }
}

fn init() -> ParamSet {
    ParamSet::init(runtime().manifest().arch("lenet").unwrap(), 0)
}

#[test]
fn scheduler_kind_selects_engines() {
    // The by-name dispatch drives the same runs the engine facades do;
    // it now consumes a RunSpec (the experiment API's description).
    let spec = |c: TrainConfig| omnivore::api::RunSpec {
        train: c,
        options: EngineOptions::default(),
        ..omnivore::api::RunSpec::default()
    };
    let (report, _params) =
        SchedulerKind::SimClock.run(runtime(), &spec(cfg(1, 8)), init()).unwrap();
    assert_eq!(report.records.len(), 8);
    let (report, _params) =
        SchedulerKind::OsThreads.run(runtime(), &spec(cfg(2, 8)), init()).unwrap();
    assert_eq!(report.records.len(), 8);
}

#[test]
fn sync_parity_sim_clock_vs_os_threads() {
    // g = 1: one group, no races — the discrete-event scheduler and the
    // OS-thread scheduler execute the identical sequence of artifact
    // calls against the identical batch sequence, so the loss sequence
    // must match bit-for-bit (only the clocks differ).
    let c = cfg(1, 16);
    let sim = SimTimeEngine::new(runtime(), c.clone(), EngineOptions::default())
        .run(init())
        .unwrap();
    let thr = ThreadedEngine::new(runtime(), c).run(init()).unwrap();
    assert_eq!(sim.records.len(), 16);
    assert_eq!(thr.records.len(), 16);
    for (a, b) in sim.records.iter().zip(&thr.records) {
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.loss, b.loss, "loss diverged at seq {}", a.seq);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.conv_staleness, b.conv_staleness);
    }
}

#[test]
fn averaging_tau1_g1_matches_single_device_sgd() {
    // One replica averaged with itself every iteration IS plain
    // momentum SGD on the full_step artifact: replay it by hand and
    // demand the same loss sequence.
    let mut c = cfg(1, 12);
    c.cluster = cluster::preset("1xcpu").unwrap();
    let he = HeParams::measured(1.0, 0.0, 0.1);
    let report =
        AveragingEngine::new(runtime(), c.clone(), 1, he).run(init()).unwrap();
    assert_eq!(report.records.len(), 12);

    let data = SyntheticDataset::for_arch("lenet", c.seed);
    let artifact = format!("{}_{}_full_step_b{}", c.arch, c.variant, c.batch);
    let mut w: Vec<HostTensor> = init().tensors().to_vec();
    let mut v: Vec<HostTensor> =
        w.iter().map(|t| HostTensor::zeros(t.shape())).collect();
    for (i, rec) in report.records.iter().enumerate() {
        let batch = data.batch((c.seed << 20) + i as u64, c.batch);
        let mut lits = vec![
            to_literal(&batch.images).unwrap(),
            labels_literal(&batch.labels).unwrap(),
        ];
        for t in &w {
            lits.push(to_literal(t).unwrap());
        }
        let outs = runtime().execute_literals(&artifact, &lits).unwrap();
        let loss = from_literal(&outs[0]).unwrap().scalar().unwrap();
        assert_eq!(loss, rec.loss, "loss diverged at iteration {i}");
        for ((wi, vi), go) in w.iter_mut().zip(v.iter_mut()).zip(&outs[2..]) {
            let gt = from_literal(go).unwrap();
            momentum_sgd_step(
                wi.data_mut(),
                vi.data_mut(),
                gt.data(),
                c.hyper.momentum,
                c.hyper.lr,
                c.hyper.lambda,
            );
        }
    }
}

#[test]
fn averaging_engine_honors_engine_options() {
    // Eval cadence and early stopping used to be sim-engine-only.
    let mut c = cfg(1, 2000);
    c.cluster = cluster::preset("1xcpu").unwrap();
    c.hyper = Hyper { lr: 0.03, momentum: 0.9, lambda: 5e-4 };
    let he = HeParams::measured(1.0, 0.0, 0.1);
    let opts = EngineOptions {
        eval_every: 64,
        stop_at_train_acc: Some(0.9),
        he_override: Some(he),
        ..Default::default()
    };
    let report =
        AveragingEngine::with_options(runtime(), c, 1, opts).run(init()).unwrap();
    assert!(
        report.records.len() < 1500,
        "averaging early stop did not fire: ran {}",
        report.records.len()
    );
    assert!(!report.evals.is_empty(), "averaging produced no held-out evals");
}

#[test]
fn heterogeneous_cluster_reports_per_group_timing() {
    // One GPU-profile group + three CPU-profile groups (hetero-s): the
    // GPU group must complete more iterations at a shorter cadence, and
    // the report must say which group ran on what.
    let mut c = cfg(4, 120);
    c.cluster = cluster::preset("hetero-s").unwrap();
    let opts = EngineOptions {
        dist: ServiceDist::Deterministic,
        eval_every: 40,
        ..Default::default()
    };
    let report = SimTimeEngine::new(runtime(), c, opts).run(init()).unwrap();
    assert_eq!(report.records.len(), 120);
    assert_eq!(report.group_stats.len(), 4);
    // Straggler-aware eval placement: every held-out eval runs on the
    // fastest group's machines (the GPU group) and records what it
    // would cost there.
    assert!(!report.evals.is_empty());
    for e in &report.evals {
        assert_eq!(e.group, 0, "eval placed on group {} not the GPU group", e.group);
        assert!(e.cost > 0.0, "eval cost not recorded");
    }
    let gpu = &report.group_stats[0];
    assert_eq!(gpu.device, "gpu");
    for cpu in &report.group_stats[1..] {
        assert_eq!(cpu.device, "cpu");
        assert!(
            gpu.iters > cpu.iters,
            "gpu group {} iters vs cpu group {} iters {}",
            gpu.iters,
            cpu.group,
            cpu.iters
        );
        assert!(
            gpu.mean_iter_gap < cpu.mean_iter_gap,
            "gpu gap {} vs cpu gap {}",
            gpu.mean_iter_gap,
            cpu.mean_iter_gap
        );
    }
    // Staleness accounting still covers every group.
    let total: u64 = report.group_stats.iter().map(|s| s.iters).sum();
    assert_eq!(total, 120);
}

#[test]
fn dynamic_batch_report_and_prediction() {
    // --dynamic-batch on hetero-s: shares are FLOPS-proportional (gpu
    // group largest, summing to the global batch), the profile-aware
    // cadence prediction lands in the report, and the measured per-group
    // gap tracks it for the groups the queue-free model covers.
    let mut c = cfg(4, 160);
    c.cluster = cluster::preset("hetero-s").unwrap();
    c.dynamic_batch = true;
    // Conv-bound measured HE params: the queue-free cadence model is
    // the whole story, so the spread comparison below is deterministic.
    let opts = || EngineOptions {
        dist: ServiceDist::Deterministic,
        he_override: Some(HeParams::measured(1.0, 0.002, 0.01)),
        ..Default::default()
    };
    let report = SimTimeEngine::new(runtime(), c.clone(), opts()).run(init()).unwrap();
    assert_eq!(report.group_stats.len(), 4);
    let shares: Vec<usize> = report.group_stats.iter().map(|s| s.batch_share).collect();
    assert_eq!(shares.iter().sum::<usize>(), c.batch, "shares {shares:?}");
    assert!(shares[0] > shares[1], "gpu group must get the bigger share: {shares:?}");
    for s in &report.group_stats {
        assert!(s.predicted_iter_gap > 0.0, "group {} missing prediction", s.group);
    }
    // Dynamic shares narrow the cadence spread vs the equal split.
    let mut eq = c.clone();
    eq.dynamic_batch = false;
    let equal = SimTimeEngine::new(runtime(), eq, opts()).run(init()).unwrap();
    let spread = |r: &omnivore::engine::TrainReport| {
        let gaps: Vec<f64> = r
            .group_stats
            .iter()
            .filter(|s| s.iters > 1)
            .map(|s| s.mean_iter_gap)
            .collect();
        gaps.iter().cloned().fold(0.0f64, f64::max)
            - gaps.iter().cloned().fold(f64::INFINITY, f64::min)
    };
    assert!(
        spread(&report) < spread(&equal),
        "dynamic spread {} vs equal spread {}",
        spread(&report),
        spread(&equal)
    );
    // Equal-split reports still carry their (uniform) shares.
    let eq_shares: Vec<usize> = equal.group_stats.iter().map(|s| s.batch_share).collect();
    assert_eq!(eq_shares, vec![8, 8, 8, 8]);
}

/// Per-group mean completion gap spread (max − min) over the records at
/// or after `after` — the measured straggler stall of the steady state.
fn tail_stall(report: &omnivore::engine::TrainReport, after: f64, groups: usize) -> f64 {
    let mut last = vec![None; groups];
    let mut sum = vec![0.0f64; groups];
    let mut n = vec![0u64; groups];
    for r in &report.records {
        if let Some(prev) = last[r.group] {
            if r.vtime >= after {
                sum[r.group] += r.vtime - prev;
                n[r.group] += 1;
            }
        }
        last[r.group] = Some(r.vtime);
    }
    let means: Vec<f64> = (0..groups)
        .filter(|&g| n[g] > 0)
        .map(|g| sum[g] / n[g] as f64)
        .collect();
    means.iter().cloned().fold(0.0f64, f64::max)
        - means.iter().cloned().fold(f64::INFINITY, f64::min)
}

#[test]
fn adaptive_replanning_recovers_drift_stall() {
    // The acceptance story: on `drift-s` (declared homogeneous, group 0
    // throttles 3x at vtime 6) a static plan cannot react — even
    // `--dynamic-batch` sees identical declared profiles and keeps the
    // equal split — while adaptive re-planning sheds load off the
    // throttled group and recovers most of the measured straggler
    // stall (>= 30% required; in practice far more).
    let spec = |adaptive: bool| {
        omnivore::api::RunSpec::new("lenet")
            .variant("jnp")
            .cluster_preset("drift-s")
            .unwrap()
            .groups(4)
            .lr(0.03)
            .momentum(0.6)
            .steps(160)
            .seed(0)
            .eval_every(0)
            .dist(ServiceDist::Deterministic)
            .he_override(HeParams::measured(1.0, 0.002, 0.01))
            .adaptive_batch(adaptive)
    };
    let run = |adaptive: bool| {
        let s = spec(adaptive);
        let init = s.cold_init(runtime()).unwrap();
        s.execute_from(runtime(), init).unwrap()
    };
    let (static_out, static_rep, _) = run(false);
    let (adaptive_out, adaptive_rep, _) = run(true);
    assert_eq!(static_rep.records.len(), 160);
    assert_eq!(adaptive_rep.records.len(), 160);

    // Static: one epoch, equal shares, big post-drift stall.
    assert_eq!(static_out.plan_epochs.len(), 1);
    assert_eq!(static_out.plan_epochs[0].shares, vec![8, 8, 8, 8]);
    let tail_after = 12.0; // past the step + the adaptation transient
    let static_stall = tail_stall(&static_rep, tail_after, 4);
    let adaptive_stall = tail_stall(&adaptive_rep, tail_after, 4);
    assert!(static_stall > 0.5, "static run shows no drift stall? {static_stall}");
    assert!(
        adaptive_stall < 0.7 * static_stall,
        "adaptive stall {adaptive_stall} vs static {static_stall}: < 30% cut"
    );

    // The adaptive outcome's plan trace: >= 2 epochs, monotone versions,
    // every epoch's shares summing to the batch, throttled group shed.
    let eps = &adaptive_out.plan_epochs;
    assert!(eps.len() >= 2, "no re-plan recorded: {eps:?}");
    for (i, e) in eps.iter().enumerate() {
        assert_eq!(e.version, i as u64, "versions must be dense and monotone");
        assert_eq!(e.shares.iter().sum::<usize>(), 32, "epoch {i}: {:?}", e.shares);
        assert_eq!(e.iters.len(), 4);
    }
    assert!(eps[0].since_vtime == 0.0 && eps[1].since_vtime > 0.0);
    let last = eps.last().unwrap();
    assert!(
        last.shares[0] < last.shares[1],
        "throttled group keeps the smallest share: {:?}",
        last.shares
    );
    // Final-epoch shares are what the per-group report describes.
    let shares: Vec<usize> = adaptive_rep.group_stats.iter().map(|s| s.batch_share).collect();
    assert_eq!(shares, last.shares);

    // The trace survives the run store (schema-versioned JSON).
    let dir = omnivore::util::temp_dir("adaptive-trace").unwrap();
    let store = omnivore::api::RunStore::open(&dir).unwrap();
    store.append(&adaptive_out).unwrap();
    let back = store.latest().unwrap().unwrap();
    assert_eq!(back.plan_epochs, adaptive_out.plan_epochs);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn adaptive_on_steady_homogeneous_cluster_is_bit_identical() {
    // Hysteresis regression: with nothing drifting and every group at
    // the same speed, `--adaptive-batch` must never leave the equal
    // plan — records bit-identical to the static path. (Deterministic
    // service isolates the hysteresis question from sampling noise;
    // the noise margin itself is the controller's δ, unit-tested.)
    let opts = || EngineOptions { dist: ServiceDist::Deterministic, ..Default::default() };
    let mut c = cfg(2, 48);
    c.adaptive_batch = true;
    let adaptive = SimTimeEngine::new(runtime(), c.clone(), opts()).run(init()).unwrap();
    c.adaptive_batch = false;
    let fixed = SimTimeEngine::new(runtime(), c, opts()).run(init()).unwrap();
    assert_eq!(adaptive.records.len(), fixed.records.len());
    for (a, b) in adaptive.records.iter().zip(&fixed.records) {
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.vtime, b.vtime, "clock diverged at seq {}", a.seq);
        assert_eq!(a.loss, b.loss, "loss diverged at seq {}", a.seq);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.conv_staleness, b.conv_staleness);
    }
    assert_eq!(adaptive.plan_epochs.len(), 1, "no epoch beyond the initial plan");
    assert_eq!(adaptive.plan_epochs[0].shares, vec![16, 16]);
}

/// Deterministic cpu-s spec the fault/recovery acceptance tests share:
/// measured conv-bound HE params so the crash window (vtime 6..12) lands
/// mid-run and every event time is reproducible.
fn det_spec(steps: usize) -> omnivore::api::RunSpec {
    omnivore::api::RunSpec::new("lenet")
        .variant("jnp")
        .cluster_preset("cpu-s")
        .unwrap()
        .groups(4)
        .lr(0.03)
        .momentum(0.6)
        .steps(steps)
        .seed(0)
        .eval_every(0)
        .dist(ServiceDist::Deterministic)
        .he_override(HeParams::measured(1.0, 0.002, 0.01))
}

fn run_spec(
    s: &omnivore::api::RunSpec,
) -> (omnivore::api::RunOutcome, omnivore::engine::TrainReport) {
    let init = s.cold_init(runtime()).unwrap();
    let (out, rep, _params) = s.execute_from(runtime(), init).unwrap();
    (out, rep)
}

/// Mean loss over the last 32 completed iterations.
fn window32(r: &omnivore::engine::TrainReport) -> f64 {
    let n = r.records.len();
    assert!(n >= 32, "only {n} records");
    r.records[n - 32..].iter().map(|x| x.loss as f64).sum::<f64>() / 32.0
}

#[test]
fn crash_and_rejoin_stays_within_five_percent_of_undisturbed() {
    // The churn acceptance (ROADMAP): on `faulty-s` (cpu-s, group 0
    // crashes at vtime 6 and rejoins at 12) the dead group's share
    // re-partitions to the survivors, its zombie gradients are fenced
    // (dropped and counted, never applied), and the window-32 final
    // loss lands within 5% of the undisturbed run.
    let (calm_out, calm_rep) = run_spec(&det_spec(160));
    let (fault_out, fault_rep) =
        run_spec(&det_spec(160).faults(FaultSchedule::preset("faulty-s").unwrap()));
    assert_eq!(calm_rep.records.len(), 160);
    // The chain in flight at the crash dies a zombie — its claim is the
    // one iteration the step budget loses.
    assert_eq!(fault_rep.records.len(), 159);
    // The fence fired and counted; the calm run never fences.
    assert!(fault_out.dropped_stale_publishes > 0, "no fenced publish counted");
    assert_eq!(calm_out.dropped_stale_publishes, 0);
    // Both fault events surfaced, in time order, with their group.
    let kinds: Vec<&str> =
        fault_out.fault_events.iter().map(|e| e.kind.as_str()).collect();
    assert_eq!(kinds, ["crash", "restart"]);
    assert!(fault_out.fault_events.iter().all(|e| e.group == Some(0)));
    assert_eq!(fault_out.fault_events[0].at, 6.0);
    assert_eq!(fault_out.fault_events[1].at, 12.0);
    assert!((fault_out.group_downtime[0] - 6.0).abs() < 1e-9, "{:?}", fault_out.group_downtime);
    assert!(fault_out.group_downtime[1..].iter().all(|&d| d == 0.0));
    assert!(calm_out.group_downtime.iter().all(|&d| d == 0.0));
    // Membership epochs: initial plan, share -> 0 at the crash, restored
    // at the restart — all summing to the global batch.
    let eps = &fault_out.plan_epochs;
    assert_eq!(eps.len(), 3, "{eps:?}");
    assert_eq!(eps[1].shares[0], 0, "crashed group must shed its whole share");
    assert!(eps[2].shares[0] > 0, "rejoined group must get work back");
    for e in eps {
        assert_eq!(e.shares.iter().sum::<usize>(), 32, "{:?}", e.shares);
    }
    // Six virtual seconds of downtime must not cost final loss.
    let (c, f) = (window32(&calm_rep), window32(&fault_rep));
    assert!(
        ((f - c) / c).abs() < 0.05,
        "faulty window-32 loss {f} vs undisturbed {c}"
    );
}

#[test]
fn stale_replay_fence_is_bit_identical_to_no_replay() {
    // Fencing proof: with stale replay ON the crashed group's in-flight
    // gradients are computed and *attempted* (the fence drops and counts
    // them); with replay OFF they are never attempted. If any record
    // differs between the two runs, a "dropped" publish actually touched
    // the model.
    let (replay_out, replay_rep) =
        run_spec(&det_spec(96).faults(FaultSchedule::preset("faulty-s").unwrap()));
    let (silent_out, silent_rep) = run_spec(
        &det_spec(96)
            .faults(FaultSchedule::preset("faulty-s").unwrap().without_stale_replay()),
    );
    assert!(replay_out.dropped_stale_publishes > 0, "replay mode never hit the fence");
    assert_eq!(silent_out.dropped_stale_publishes, 0, "no-replay mode published?");
    assert_eq!(replay_rep.records.len(), silent_rep.records.len());
    for (a, b) in replay_rep.records.iter().zip(&silent_rep.records) {
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.group, b.group);
        assert_eq!(a.vtime, b.vtime, "clock diverged at seq {}", a.seq);
        assert_eq!(a.loss, b.loss, "a fenced publish moved the model at seq {}", a.seq);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.conv_staleness, b.conv_staleness);
        assert_eq!(a.fc_staleness, b.fc_staleness);
    }
}

#[test]
fn empty_fault_schedule_is_structurally_inert() {
    // `faults: None` takes zero fault branches; an EMPTY schedule takes
    // all the guards but no events. Both must be bit-identical — extra
    // rng draws or reordered events would show up immediately.
    let (bare_out, bare_rep) = run_spec(&det_spec(48));
    let (empty_out, empty_rep) = run_spec(&det_spec(48).faults(FaultSchedule::empty()));
    assert!(empty_out.fault_events.is_empty());
    assert_eq!(empty_out.dropped_stale_publishes, 0);
    assert_eq!(bare_rep.records.len(), empty_rep.records.len());
    for (a, b) in bare_rep.records.iter().zip(&empty_rep.records) {
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.group, b.group);
        assert_eq!(a.vtime, b.vtime, "clock diverged at seq {}", a.seq);
        assert_eq!(a.loss, b.loss, "loss diverged at seq {}", a.seq);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.conv_staleness, b.conv_staleness);
    }
    assert_eq!(bare_out.virtual_time, empty_out.virtual_time);
}

#[test]
fn checkpoint_resume_reaches_the_uninterrupted_loss_window() {
    // Recovery through the driver: train 80 steps with periodic
    // checkpoints, then resume the full 160-step budget from the file —
    // only the remaining 80 run, the outcome says where it resumed from,
    // and the final loss window matches the uninterrupted run (velocity
    // is not checkpointed; its transient decays well within 80 steps).
    let dir = omnivore::util::temp_dir("fault-resume").unwrap();
    let ck = dir.join("half.ckpt");
    let ck_str = ck.to_str().unwrap();
    let (full_out, full_rep) = run_spec(&det_spec(160));
    assert!(full_out.resumed_from.is_none());
    let (_half_out, half_rep) =
        run_spec(&det_spec(80).checkpoint_every(40).checkpoint_path(ck_str));
    assert_eq!(half_rep.records.len(), 80);
    let (_params, steps) = omnivore::model::load_checkpoint_state(&ck).unwrap();
    assert_eq!(steps, 80, "checkpoint must carry the completed-step count");

    let resumed = det_spec(160).resume_from(ck_str);
    let rt = runtime();
    let (init, done) = resumed.initial_state(rt).unwrap();
    assert_eq!(done, 80);
    let (res_out, res_rep, _params) = resumed.execute_from_step(rt, init, done).unwrap();
    assert_eq!(res_rep.records.len(), 80, "resume must run only the remaining budget");
    assert_eq!(res_out.resumed_from.as_deref(), Some(ck_str));
    let (f, r) = (window32(&full_rep), window32(&res_rep));
    assert!(
        ((r - f) / f).abs() < 0.10,
        "resumed window-32 loss {r} vs uninterrupted {f}"
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn max_virtual_time_budget_stops_all_schedulers() {
    // The same virtual-time budget option cuts off both clock-driven
    // schedulers (threaded vtime is wall-clock, so budget it generously
    // and only check the sim + averaging clocks here).
    let opts = |tmax| EngineOptions {
        dist: ServiceDist::Deterministic,
        max_virtual_time: Some(tmax),
        ..Default::default()
    };
    let unbounded = SimTimeEngine::new(runtime(), cfg(2, 64), opts(f64::INFINITY))
        .run(init())
        .unwrap();
    let budget = unbounded.virtual_time / 4.0;
    let bounded =
        SimTimeEngine::new(runtime(), cfg(2, 64), opts(budget)).run(init()).unwrap();
    assert!(
        bounded.records.len() < unbounded.records.len(),
        "sim: {} vs {}",
        bounded.records.len(),
        unbounded.records.len()
    );

    let he = HeParams::measured(1.0, 0.0, 0.1);
    let mut c = cfg(1, 64);
    c.cluster = cluster::preset("1xcpu").unwrap();
    let avg_opts = EngineOptions {
        max_virtual_time: Some(5.0 * 1.1), // ~5 local iterations at t_local=1.1
        he_override: Some(he),
        ..Default::default()
    };
    let report =
        AveragingEngine::with_options(runtime(), c, 1, avg_opts).run(init()).unwrap();
    assert!(
        report.records.len() < 20,
        "averaging time budget ignored: {} records",
        report.records.len()
    );
}
