//! Integration: the experiment API (DESIGN.md §API) against the real
//! PJRT-backed engine — `RunSpec::execute` must reproduce the raw
//! scheduler path bit-for-bit, outcomes must roundtrip through JSON and
//! the run store, and legacy TrainConfig files must keep working.

mod common;

use common::runtime;
use omnivore::api::{RunOutcome, RunSpec, RunStore, FINAL_WINDOW};
use omnivore::baselines::BaselineSystem;
use omnivore::config::{FcMapping, Strategy, TrainConfig};
use omnivore::engine::SchedulerKind;
use omnivore::model::ParamSet;
use omnivore::util::json::Json;

fn spec(steps: usize) -> RunSpec {
    RunSpec::new("lenet")
        .cluster_preset("cpu-s")
        .unwrap()
        .sync()
        .lr(0.03)
        .momentum(0.6)
        .steps(steps)
        .seed(0)
        .eval_every(0)
}

fn init() -> ParamSet {
    ParamSet::init(runtime().manifest().arch("lenet").unwrap(), 0)
}

#[test]
fn execute_reproduces_scheduler_run_bit_for_bit() {
    // The facade must be a pure repackaging of SchedulerKind::run — on
    // cpu-s g=1 the two paths execute the identical artifact sequence,
    // so every record matches exactly.
    let s = spec(16);
    let (raw, _params) = SchedulerKind::SimClock.run(runtime(), &s, init()).unwrap();
    let (outcome, via_api, _params) = s.execute_from(runtime(), init()).unwrap();
    assert_eq!(raw.records.len(), 16);
    assert_eq!(via_api.records.len(), 16);
    for (a, b) in raw.records.iter().zip(&via_api.records) {
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.loss, b.loss, "loss diverged at seq {}", a.seq);
        assert_eq!(a.acc, b.acc);
        assert_eq!(a.vtime, b.vtime);
        assert_eq!(a.conv_staleness, b.conv_staleness);
        assert_eq!(a.fc_staleness, b.fc_staleness);
    }
    // The outcome's headline numbers ARE the report's (what the CLI
    // table prints and what --json emits).
    assert_eq!(outcome.final_loss, via_api.final_loss(FINAL_WINDOW));
    assert_eq!(outcome.final_acc, via_api.final_acc(FINAL_WINDOW));
    assert_eq!(outcome.virtual_time, via_api.virtual_time);
    assert_eq!(outcome.iters, 16);
    assert_eq!(outcome.groups, via_api.groups);
    assert_eq!(outcome.conv_staleness_mean, via_api.conv_staleness.mean());
    assert_eq!(outcome.scheduler, "sim-clock");
}

#[test]
fn one_call_execute_matches_cold_init() {
    // execute() inits from the manifest + seed; identical to the
    // explicit cold-init path.
    let s = spec(12);
    let a = s.execute(runtime()).unwrap();
    let (b, _report, _params) = s.execute_from(runtime(), init()).unwrap();
    assert_eq!(a.final_loss, b.final_loss);
    assert_eq!(a.final_acc, b.final_acc);
    assert_eq!(a.virtual_time, b.virtual_time);
}

#[test]
fn real_outcome_roundtrips_and_persists() {
    let s = spec(12).tag("api-test").eval_every(4);
    let outcome = s.execute(runtime()).unwrap();
    assert!(outcome.final_eval_acc.is_some(), "eval cadence 4 must record evals");
    // JSON roundtrip of a REAL outcome (not a synthetic report).
    let j = outcome.to_json().dump();
    let back = RunOutcome::from_json(&Json::parse(&j).unwrap()).unwrap();
    assert_eq!(back.final_loss, outcome.final_loss);
    assert_eq!(back.final_acc, outcome.final_acc);
    assert_eq!(back.virtual_time, outcome.virtual_time);
    assert_eq!(back.final_eval_acc, outcome.final_eval_acc);
    assert_eq!(back.predicted_iter_time, outcome.predicted_iter_time);
    assert_eq!(back.spec.train.steps, 12);
    // Store roundtrip: append, then look it up by tag and as latest.
    let dir = omnivore::util::temp_dir("it-api-store").unwrap();
    let store = RunStore::open(&dir).unwrap();
    store.append(&outcome).unwrap();
    let latest = store.latest().unwrap().unwrap();
    assert_eq!(latest.final_loss, outcome.final_loss);
    assert_eq!(store.by_tag("api-test").unwrap().len(), 1);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn legacy_train_config_file_drives_a_run() {
    // A pre-API config file (bare TrainConfig JSON) must still load and
    // execute — `omnivore train --config old.json` keeps working.
    let cfg = TrainConfig {
        arch: "lenet".into(),
        steps: 8,
        hyper: omnivore::config::Hyper { lr: 0.03, ..Default::default() },
        ..TrainConfig::default()
    };
    let dir = omnivore::util::temp_dir("it-api-legacy").unwrap();
    let path = dir.join("old.json");
    std::fs::write(&path, cfg.to_json().dump()).unwrap();
    let s = RunSpec::from_json_file(path.to_str().unwrap()).unwrap();
    assert_eq!(s.train.arch, "lenet");
    assert_eq!(s.train.steps, 8);
    assert_eq!(s.scheduler, SchedulerKind::SimClock);
    let outcome = s.execute(runtime()).unwrap();
    assert_eq!(outcome.iters, 8);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn spec_file_artifacts_dir_resolution() {
    // The precedence the CLI applies: explicit flag > spec file > default.
    let s = RunSpec::default().artifacts_dir("from-spec");
    let parsed =
        RunSpec::from_json(&Json::parse(&s.to_json().dump()).unwrap()).unwrap();
    assert_eq!(parsed.train.artifacts_dir, "from-spec");
    assert_eq!(
        omnivore::api::resolve_artifacts_dir(None, Some(&parsed.train.artifacts_dir)),
        "from-spec"
    );
    assert_eq!(
        omnivore::api::resolve_artifacts_dir(
            Some("from-flag"),
            Some(&parsed.train.artifacts_dir)
        ),
        "from-flag"
    );
}

#[test]
fn baseline_spec_runs_the_envelope() {
    // A baseline on the spec applies the competitor's strategy envelope
    // at execute time: mxnet-sync forces sync + unmerged FC.
    let s = spec(8).groups(4).baseline(BaselineSystem::MxnetSync);
    let cfg = s.effective_config();
    assert_eq!(cfg.strategy, Strategy::Sync);
    assert_eq!(cfg.fc_mapping, FcMapping::Unmerged);
    let outcome = s.execute(runtime()).unwrap();
    assert_eq!(outcome.groups, 1, "baseline envelope must win over the spec's g");
}

#[test]
fn scheduler_choice_in_spec_is_honored() {
    let s = spec(8).scheduler(SchedulerKind::OsThreads);
    let outcome = s.execute(runtime()).unwrap();
    assert_eq!(outcome.scheduler, "os-threads");
    assert_eq!(outcome.iters, 8);
}
