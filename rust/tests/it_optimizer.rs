//! Integration: the optimizer stack against the real PJRT-backed engine —
//! grid search picks trainable settings, Algorithm 1 runs end-to-end, and
//! the implicit-momentum machinery measures what Theorem 1 predicts.

mod common;

use common::runtime;
use omnivore::api::RunSpec;
use omnivore::config::Hyper;
use omnivore::model::ParamSet;
use omnivore::optimizer::grid_search::{grid_search, GridSpec};
use omnivore::optimizer::se_model;
use omnivore::optimizer::{AutoOptimizer, EngineTrainer, HeParams, Trainer};
use omnivore::sim::ServiceDist;

fn trainer(seed: u64) -> EngineTrainer<'static> {
    EngineTrainer::new(
        runtime(),
        RunSpec::new("lenet")
            .cluster_preset("cpu-s")
            .unwrap()
            .seed(seed)
            .eval_every(0),
    )
}

fn init() -> ParamSet {
    ParamSet::init(runtime().manifest().arch("lenet").unwrap(), 0)
}

#[test]
fn trainer_reports_cluster_size() {
    assert_eq!(trainer(0).n_machines(), 8);
}

#[test]
fn trainer_resolves_baseline_instead_of_reapplying_it() {
    // A baseline envelope left on the trainer's spec would re-apply on
    // every probe (effective_config forcing e.g. MXNet's fixed strategy
    // and 0.9 momentum), silently overriding the exact (g, mu) the
    // optimizer sweeps. The constructor must bake it into `train` once
    // and clear it.
    let spec = RunSpec::new("lenet")
        .cluster_preset("cpu-s")
        .unwrap()
        .eval_every(0)
        .baseline(omnivore::baselines::BaselineSystem::MxnetAsync);
    let t = EngineTrainer::new(runtime(), spec);
    assert!(t.spec.baseline.is_none());
    assert_eq!(t.spec.train.fc_mapping, omnivore::config::FcMapping::Unmerged);
}

#[test]
fn grid_search_rejects_diverging_eta() {
    let mut t = trainer(0);
    let spec = GridSpec {
        momenta: vec![0.9],
        etas: vec![5.0, 0.03], // 5.0 diverges on this model
        probe_steps: 24,
        loss_window: 8,
        mu_last: None,
        eta_last: None,
        lambda: 5e-4,
    };
    let out = grid_search(&mut t, &init(), 1, &spec).unwrap();
    assert_eq!(out.best.lr, 0.03, "diverging eta must lose");
    assert!(out.best_loss.is_finite());
}

#[test]
fn algorithm1_end_to_end_on_real_engine() {
    let mut t = trainer(0);
    let arch = runtime().manifest().arch("lenet").unwrap();
    let he = HeParams::derive(&cluster::preset("cpu-s").unwrap(), arch, 32, 0.5);
    let opt = AutoOptimizer {
        cold_probe_steps: 32,
        epochs: 1,
        epoch_steps: 96,
        probe_steps: 16,
        warmup_steps: 48,
        lambda: 5e-4,
        skip_cold_start: false,
    };
    let (trace, params) = opt.run(&mut t, init(), &he).unwrap();
    assert_eq!(trace.epochs.len(), 1);
    let e = &trace.epochs[0];
    assert!(e.g >= 1 && e.g <= 8);
    assert!(e.final_loss.is_finite());
    // The optimizer must have made progress from cold init (ln 10 = 2.30).
    assert!(e.final_loss < 2.3, "epoch loss {}", e.final_loss);
    assert_eq!(params.num_params(), init().num_params());
}

#[test]
fn async_behaves_like_added_momentum_on_real_engine() {
    // Behavioral form of Theorem 1 on the real engine: at g=4 the tuned
    // explicit momentum is *lower* than at g=1 — i.e. asynchrony supplies
    // the difference. We verify by comparing loss at matched total
    // momentum: (g=1, mu=0.9) vs (g=4, mu=0.6) should both train well,
    // while (g=4, mu=0.9) does not (over-momentum).
    let mut t = trainer(0);
    t.spec.options.dist = ServiceDist::Exponential;
    let lr = 0.03;
    let run = |t: &mut EngineTrainer, g: usize, mu: f32| {
        let (rep, _) = t
            .train(g, Hyper { lr, momentum: mu, lambda: 5e-4 }, 150, &init())
            .unwrap();
        rep.final_loss(24)
    };
    let sync_std = run(&mut t, 1, 0.9);
    let async_comp = run(&mut t, 4, se_model::compensated_momentum(0.9, 4) as f32);
    let async_std = run(&mut t, 4, 0.9);
    assert!(sync_std < 0.5, "sync baseline must train: {sync_std}");
    assert!(async_comp < 0.5, "compensated async must train: {async_comp}");
    assert!(
        async_std > 2.0 * async_comp.max(0.01),
        "over-momentum async must be clearly worse: {async_std} vs {async_comp}"
    );
}

#[test]
fn theorem1_exact_on_quadratic() {
    // The theorem's own setting (exponential service, linear gradients):
    // measured implicit momentum tracks 1 - 1/g.
    use omnivore::optimizer::quadratic::AsyncQuadratic;
    let q = AsyncQuadratic::default();
    for g in [2usize, 4] {
        let measured = q.measure_implicit_momentum(g, 150, 300, 9);
        let predicted = se_model::implicit_momentum(g);
        assert!(
            (measured - predicted).abs() < 0.12,
            "g={g}: {measured:.3} vs {predicted:.3}"
        );
    }
}

#[test]
fn compensated_momentum_keeps_async_stable() {
    // At g=4 the standard mu=0.9 gives total momentum ~0.975 (diverges or
    // stalls); the compensated mu keeps total at 0.9.
    let mu_comp = se_model::compensated_momentum(0.9, 4) as f32;
    assert!((mu_comp - 0.6).abs() < 1e-6);
    let mut t = trainer(0);
    let (rep_tuned, _) = t
        .train(4, Hyper { lr: 0.03, momentum: mu_comp, lambda: 5e-4 }, 160, &init())
        .unwrap();
    let (rep_std, _) = t
        .train(4, Hyper { lr: 0.03, momentum: 0.9, lambda: 5e-4 }, 160, &init())
        .unwrap();
    let tuned = rep_tuned.final_loss(24);
    let std = rep_std.final_loss(24);
    assert!(
        tuned < std,
        "momentum tuning must help at g=4: tuned {tuned} vs standard {std}"
    );
}
