//! Replays `fuzz/corpus/` through the matching untrusted parse surfaces
//! (DESIGN.md §Analysis). Every `ok_*` file must parse cleanly (and,
//! for the JSON surfaces, reach a parse → serialize → parse fixpoint);
//! every `bad_*` file must be rejected with a validation `Err`. A panic
//! or a flipped outcome on any corpus file is a regression against a
//! previously-minimized fuzzer finding.

use std::fs;
use std::path::{Path, PathBuf};

use omnivore::api::RunSpec;
use omnivore::config::{FaultSchedule, ProfileDrift};
use omnivore::data::plan_script;
use omnivore::model::load_checkpoint_state;
use omnivore::util::json::Json;

fn corpus(sub: &str) -> Vec<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fuzz/corpus").join(sub);
    let mut files: Vec<PathBuf> = fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .map(|e| e.expect("corpus dir entry").path())
        .filter(|p| p.is_file())
        .collect();
    files.sort();
    assert!(!files.is_empty(), "empty corpus {}", dir.display());
    files
}

/// `ok_` files must be accepted, `bad_` files rejected; anything else
/// in the corpus is a naming mistake.
fn expect_ok(path: &Path) -> bool {
    let name = path.file_name().expect("file name").to_string_lossy();
    if name.starts_with("ok_") {
        true
    } else {
        assert!(name.starts_with("bad_"), "corpus file {name} must be named ok_* or bad_*");
        false
    }
}

fn check_json_surface(sub: &str, parse_dump: fn(&Json) -> anyhow::Result<Json>) {
    for path in corpus(sub) {
        let name = path.display();
        let text = fs::read_to_string(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        let outcome = Json::parse(&text).and_then(|v| parse_dump(&v));
        if expect_ok(&path) {
            let d1 = outcome.unwrap_or_else(|e| panic!("{name}: must parse: {e}")).dump();
            let v2 = Json::parse(&d1).unwrap_or_else(|e| panic!("{name}: reparse: {e}"));
            let d2 = parse_dump(&v2).unwrap_or_else(|e| panic!("{name}: revalidate: {e}")).dump();
            assert_eq!(d1, d2, "{name}: parse -> serialize -> parse is not a fixpoint");
        } else {
            assert!(outcome.is_err(), "{name}: hostile input was accepted");
        }
    }
}

#[test]
fn runspec_corpus() {
    check_json_surface("runspec", |v| RunSpec::from_json(v).map(|s| s.to_json()));
}

#[test]
fn fault_corpus() {
    check_json_surface("fault", |v| FaultSchedule::from_json(v).map(|s| s.to_json()));
}

#[test]
fn drift_corpus() {
    check_json_surface("drift", |v| ProfileDrift::from_json(v).map(|d| d.to_json()));
}

#[test]
fn checkpoint_corpus() {
    for path in corpus("checkpoint") {
        let name = path.display();
        let outcome = load_checkpoint_state(&path);
        if expect_ok(&path) {
            let (params, steps) =
                outcome.unwrap_or_else(|e| panic!("{name}: must load: {e}"));
            assert!(params.num_params() > 0, "{name}: loaded an empty ParamSet");
            if path.file_name().is_some_and(|n| n == "ok_tiny.ckpt") {
                assert_eq!(steps, 3, "{name}: was saved at step 3");
            }
        } else {
            assert!(outcome.is_err(), "{name}: corrupt container was accepted");
        }
    }
}

#[test]
fn serve_corpus() {
    use omnivore::serve::http::{read_request, Request};
    use std::io::{Cursor, Read};

    // Same small cap the fuzzer replays with, so cap-triggering corpus
    // files stay meaningful.
    const MAX_BODY: usize = 4096;

    /// One byte per read — the slowloris delivery shape.
    struct Drip<'a>(&'a [u8]);

    impl Read for Drip<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            match self.0.split_first() {
                Some((&b, rest)) if !buf.is_empty() => {
                    buf[0] = b;
                    self.0 = rest;
                    Ok(1)
                }
                _ => Ok(0),
            }
        }
    }

    fn sig(r: Result<Request, omnivore::serve::http::ParseError>) -> String {
        match r {
            Ok(req) => format!(
                "ok {:?} {} headers={:?} body={:?}",
                req.method, req.path, req.headers, req.body
            ),
            Err(e) => format!("err {e}"),
        }
    }

    for path in corpus("serve") {
        let name = path.display();
        let bytes = fs::read(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        let buffered = sig(read_request(&mut Cursor::new(&bytes[..]), MAX_BODY));
        let dripped = sig(read_request(&mut Drip(&bytes), MAX_BODY));
        assert_eq!(buffered, dripped, "{name}: delivery chunking changed the parse");
        if expect_ok(&path) {
            assert!(buffered.starts_with("ok "), "{name}: must parse: {buffered}");
        } else {
            assert!(buffered.starts_with("err "), "{name}: hostile request was accepted");
        }
    }
}

#[test]
fn plan_corpus() {
    for path in corpus("plan") {
        let name = path.display();
        let text = fs::read_to_string(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        let outcome = Json::parse(&text).and_then(|v| plan_script::replay(&v).map(|_| ()));
        if expect_ok(&path) {
            outcome.unwrap_or_else(|e| panic!("{name}: must replay: {e}"));
        } else {
            assert!(outcome.is_err(), "{name}: hostile script was accepted");
        }
    }
}
