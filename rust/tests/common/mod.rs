//! Shared test fixtures: one PJRT runtime per test binary.

use std::sync::OnceLock;

use omnivore::runtime::Runtime;

static RT: OnceLock<Runtime> = OnceLock::new();

/// Process-wide runtime over the repo's artifacts directory.
pub fn runtime() -> &'static Runtime {
    RT.get_or_init(|| {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::load(dir).expect("artifacts built? run `make artifacts`")
    })
}
