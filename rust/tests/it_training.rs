//! Integration: the full training stack (topology + engines) over real
//! artifacts — staleness semantics, k-invariance, determinism, merged-FC
//! guarantees, and actual learning.

mod common;

use common::runtime;
use omnivore::config::{cluster, FcMapping, Hyper, Strategy, TrainConfig};
use omnivore::coordinator::Topology;
use omnivore::data::SyntheticDataset;
use omnivore::engine::{EngineOptions, SimTimeEngine, ThreadedEngine};
use omnivore::model::ParamSet;
use omnivore::sim::ServiceDist;

fn cfg(groups: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        arch: "lenet".into(),
        variant: "jnp".into(),
        cluster: cluster::preset("cpu-s").unwrap(),
        strategy: Strategy::Groups(groups),
        hyper: Hyper { lr: 0.03, momentum: 0.6, lambda: 5e-4 },
        steps,
        seed: 0,
        ..TrainConfig::default()
    }
}

fn init() -> ParamSet {
    ParamSet::init(runtime().manifest().arch("lenet").unwrap(), 0)
}

#[test]
fn sync_run_is_deterministic() {
    let e = |seed| {
        let mut c = cfg(1, 12);
        c.seed = seed;
        SimTimeEngine::new(runtime(), c, EngineOptions::default()).run(init()).unwrap()
    };
    let a = e(1);
    let b = e(1);
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.loss, y.loss);
        assert_eq!(x.vtime, y.vtime);
    }
    let c = e(2);
    assert_ne!(a.records[0].loss, c.records[0].loss);
}

#[test]
fn staleness_matches_group_count() {
    for g in [1usize, 2, 4] {
        let report = SimTimeEngine::new(runtime(), cfg(g, 12 * g), EngineOptions::default())
            .run(init())
            .unwrap();
        let mean = report.conv_staleness.mean();
        // Steady state staleness -> g-1 (warmup pulls it slightly down).
        assert!(
            (mean - (g as f64 - 1.0)).abs() < 0.6,
            "g={g}: mean staleness {mean}"
        );
        // Merged FC: identically zero.
        assert_eq!(report.fc_staleness.total_staleness, 0, "g={g}");
    }
}

#[test]
fn unmerged_fc_sees_staleness() {
    let mut c = cfg(4, 40);
    c.fc_mapping = FcMapping::Unmerged;
    let report =
        SimTimeEngine::new(runtime(), c, EngineOptions::default()).run(init()).unwrap();
    assert!(
        report.fc_staleness.mean() > 1.0,
        "unmerged FC must be stale: {}",
        report.fc_staleness.mean()
    );
}

#[test]
fn group_size_invariance_of_first_update() {
    // g=1 with k=2 vs k=4 computes the same full-batch gradient, so the
    // model after one iteration must be identical (up to fp reduction
    // order across microbatches, which is exact here: same artifacts).
    let run_k = |machines: usize| {
        let mut c = cfg(1, 1);
        c.cluster = cluster::preset("cpu-s").unwrap();
        c.cluster.machines = machines + 1;
        let topo = Topology::build(&c, runtime(), init()).unwrap();
        let engine = SimTimeEngine::new(runtime(), c, EngineOptions::default());
        engine.run_topology(&topo).unwrap();
        topo.current_params()
    };
    let p2 = run_k(2);
    let p4 = run_k(4);
    for (a, b) in p2.tensors().iter().zip(p4.tensors()) {
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 2e-5, "{x} vs {y}");
        }
    }
}

#[test]
fn async_hardware_efficiency_beats_sync() {
    let opts = EngineOptions { dist: ServiceDist::Deterministic, ..Default::default() };
    let sync = SimTimeEngine::new(runtime(), cfg(1, 24), opts.clone()).run(init()).unwrap();
    let async_ =
        SimTimeEngine::new(runtime(), cfg(8, 24), opts).run(init()).unwrap();
    assert!(
        async_.mean_iter_time() < sync.mean_iter_time(),
        "async {} sync {}",
        async_.mean_iter_time(),
        sync.mean_iter_time()
    );
}

#[test]
fn training_actually_learns() {
    let mut c = cfg(1, 220);
    c.hyper = Hyper { lr: 0.03, momentum: 0.9, lambda: 5e-4 };
    let opts = EngineOptions { eval_every: 64, ..Default::default() };
    let report = SimTimeEngine::new(runtime(), c, opts).run(init()).unwrap();
    assert!(
        report.final_acc(32) > 0.9,
        "train acc after 220 iters: {}",
        report.final_acc(32)
    );
    // Held-out eval also learned (same distribution).
    let last_eval = report.evals.last().unwrap();
    assert!(last_eval.acc > 0.8, "eval acc {}", last_eval.acc);
}

#[test]
fn early_stop_on_target_accuracy() {
    let mut c = cfg(1, 4000);
    c.hyper = Hyper { lr: 0.03, momentum: 0.9, lambda: 5e-4 };
    let opts = EngineOptions { stop_at_train_acc: Some(0.9), ..Default::default() };
    let report = SimTimeEngine::new(runtime(), c, opts).run(init()).unwrap();
    assert!(
        report.records.len() < 3000,
        "early stop did not fire: ran {}",
        report.records.len()
    );
}

#[test]
fn divergence_stops_run() {
    let mut c = cfg(2, 4000);
    c.hyper = Hyper { lr: 50.0, momentum: 0.9, lambda: 0.0 }; // guaranteed blow-up
    let report =
        SimTimeEngine::new(runtime(), c, EngineOptions::default()).run(init()).unwrap();
    assert!(report.records.len() < 4000, "diverged run must stop early");
    assert!(report.diverged());
}

#[test]
fn threaded_engine_matches_semantics() {
    let report = ThreadedEngine::new(runtime(), cfg(4, 24)).run(init()).unwrap();
    assert_eq!(report.groups, 4);
    assert_eq!(report.records.len(), 24); // claim-based budget: exactly cfg.steps
    assert_eq!(report.fc_staleness.total_staleness, 0); // merged FC serializes
    assert!(report.conv_staleness.mean() > 0.5); // real races produce staleness
    // Records are globally ordered with deterministic seq assignment.
    for (i, r) in report.records.iter().enumerate() {
        assert_eq!(r.seq, i as u64);
    }
}

#[test]
fn threaded_engine_honors_eval_and_early_stop() {
    // Pre-driver, the threaded engine silently ignored BOTH of these
    // EngineOptions fields; the unified driver gives it them for free.
    let mut c = cfg(2, 4000);
    c.hyper = Hyper { lr: 0.03, momentum: 0.9, lambda: 5e-4 };
    let opts = EngineOptions {
        eval_every: 32,
        stop_at_train_acc: Some(0.9),
        ..Default::default()
    };
    let report = ThreadedEngine::with_options(runtime(), c, opts).run(init()).unwrap();
    assert!(
        report.records.len() < 3000,
        "threaded early stop did not fire: ran {}",
        report.records.len()
    );
    assert!(
        !report.evals.is_empty(),
        "threaded engine produced no held-out evals"
    );
    let last_eval = report.evals.last().unwrap();
    assert!(last_eval.acc > 0.5, "eval acc {}", last_eval.acc);
}

#[test]
fn eval_batch_disjoint_from_training() {
    let data = SyntheticDataset::for_arch("lenet", 0);
    let eval = data.eval_batch(32);
    for i in 0..64 {
        assert_ne!(eval.images, data.batch(i, 32).images);
    }
}
