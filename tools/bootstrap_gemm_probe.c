/* Bootstrap probe for the BENCH_l3.json GEMM rows on a box without a
 * Rust toolchain.
 *
 * Mirrors the two schedules in rust/src/backend/kernels.rs at
 * threads = 1, op-for-op:
 *
 *   unpacked — the PR 7 C-tile-stationary reference (`gemm_unpacked`):
 *     row tiles of pick_tile(m,120) x pick_tile(n,512), k-blocked by
 *     pick_tile(k,288), plain triple loop over the tile;
 *   packed   — the BLIS-style microkernel path (`gemm`): A packed into
 *     MR=6 row strips, B into NR=16 column strips, 6x16 register
 *     accumulator, ascending-k.
 *
 * Compile WITHOUT fp contraction so the FLOP mix matches rustc (which
 * never contracts a*b+c into fma by default):
 *
 *   cc -O3 -march=native -ffp-contract=off -o probe \
 *       tools/bootstrap_gemm_probe.c && ./probe
 *
 * Prints the two 256^3 GFLOP/s numbers and their ratio; paste them into
 * BENCH_l3.json (keys gemm_256x256x256_t1 and
 * gemm_256x256x256_t1_unpacked, "bootstrap": true stays set). CI's
 * check_bench_regression.py asserts packed >= 1.5x unpacked on the
 * fresh Rust run; this probe is how that claim was validated when the
 * baseline was seeded.
 */
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define MR 6
#define NR 16

static size_t ceil_to(size_t n, size_t align) {
    return (n + align - 1) / align * align;
}

/* pick_block from kernels.rs: near-equal split, aligned up. */
static size_t pick_block(size_t n, size_t max_block, size_t align) {
    if (n == 0) n = 1;
    if (n <= max_block) return ceil_to(n, align);
    size_t n_blocks = (n + max_block - 1) / max_block;
    return ceil_to((n + n_blocks - 1) / n_blocks, align);
}

static double now_secs(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

/* ---- unpacked reference (gemm_unpacked_into, threads = 1) ---- */
static void gemm_unpacked(float *c, const float *a, const float *b, size_t m,
                          size_t k, size_t n) {
    size_t tn = pick_block(n, 512, 8);
    if (tn > n) tn = n;
    size_t tk = pick_block(k, 288, 8);
    size_t tm = pick_block(m, 120, 8);
    float *acc = malloc(tm * tn * sizeof(float));
    for (size_t i0 = 0; i0 < m; i0 += tm) {
        size_t il = (m - i0 < tm) ? m - i0 : tm;
        for (size_t j0 = 0; j0 < n; j0 += tn) {
            size_t jl = (n - j0 < tn) ? n - j0 : tn;
            memset(acc, 0, il * jl * sizeof(float));
            for (size_t k0 = 0; k0 < k; k0 += tk) {
                size_t kl = (k - k0 < tk) ? k - k0 : tk;
                for (size_t ii = 0; ii < il; ii++) {
                    const float *arow = a + (i0 + ii) * k + k0;
                    float *crow = acc + ii * jl;
                    for (size_t kk = 0; kk < kl; kk++) {
                        float av = arow[kk];
                        const float *brow = b + (k0 + kk) * n + j0;
                        for (size_t jj = 0; jj < jl; jj++)
                            crow[jj] += av * brow[jj];
                    }
                }
            }
            for (size_t ii = 0; ii < il; ii++)
                memcpy(c + (i0 + ii) * n + j0, acc + ii * jl,
                       jl * sizeof(float));
        }
    }
    free(acc);
}

/* ---- packed microkernel path (gemm_fused_on, threads = 1) ----
 *
 * The register tile is written with GCC vector extensions (one NR-wide
 * f32 lane per accumulator row, so the 6x16 tile is 6 vector registers)
 * because gcc 10's autovectorizer only finds 4-wide SSE in the plain-C
 * nest; LLVM (what rustc uses) emits this shape from the scalar Rust
 * microkernel on its own. Per output element it is still one mul and
 * one add per k, ascending — the lane split changes which elements
 * share an instruction, never the per-element op sequence, so results
 * stay bitwise identical to the scalar unpacked path (checked in
 * main). */
typedef float vnr __attribute__((vector_size(NR * 4), aligned(4)));

static inline vnr splat(float x) {
    return (vnr){x, x, x, x, x, x, x, x, x, x, x, x, x, x, x, x};
}

static void microkernel(float *restrict c, size_t ldc,
                        const float *restrict ap, const float *restrict bp,
                        size_t kc, size_t mr, size_t nr, int first) {
    vnr acc[MR];
    for (size_t r = 0; r < MR; r++) acc[r] = splat(0.0f);
    if (!first) {
        float edge[MR][NR];
        memset(edge, 0, sizeof(edge));
        for (size_t r = 0; r < mr; r++)
            for (size_t j = 0; j < nr; j++) edge[r][j] = c[r * ldc + j];
        for (size_t r = 0; r < MR; r++) acc[r] = *(const vnr *)&edge[r][0];
    }
    for (size_t kk = 0; kk < kc; kk++) {
        const float *restrict av = ap + kk * MR;
        vnr b0 = *(const vnr *)(bp + kk * NR);
        for (size_t r = 0; r < MR; r++) acc[r] += splat(av[r]) * b0;
    }
    float out[MR][NR];
    for (size_t r = 0; r < MR; r++) *(vnr *)&out[r][0] = acc[r];
    for (size_t r = 0; r < mr; r++)
        for (size_t j = 0; j < nr; j++) c[r * ldc + j] = out[r][j];
}

static void gemm_packed(float *c, const float *a, const float *b, size_t m,
                        size_t k, size_t n) {
    size_t mc = pick_block(m, 120, MR);
    size_t kc = pick_block(k, 288, 1);
    size_t nc = pick_block(n, 512, NR);
    float *apack = malloc(mc * kc * sizeof(float));
    float *bpack = malloc(nc * kc * sizeof(float));
    for (size_t jc = 0; jc < n; jc += nc) {
        size_t jl = (n - jc < nc) ? n - jc : nc;
        for (size_t pc = 0; pc < k; pc += kc) {
            size_t kl = (k - pc < kc) ? k - pc : kc;
            int first = pc == 0;
            /* pack B: NR-wide column strips, kl deep, zero-padded */
            for (size_t s = 0; s * NR < jl; s++) {
                float *dst = bpack + s * kl * NR;
                size_t w = (jl - s * NR < NR) ? jl - s * NR : NR;
                for (size_t kk = 0; kk < kl; kk++) {
                    const float *src = b + (pc + kk) * n + jc + s * NR;
                    for (size_t j = 0; j < w; j++) dst[kk * NR + j] = src[j];
                    for (size_t j = w; j < NR; j++) dst[kk * NR + j] = 0.0f;
                }
            }
            for (size_t ic = 0; ic < m; ic += mc) {
                size_t il = (m - ic < mc) ? m - ic : mc;
                /* pack A: MR-tall row strips, kl deep, zero-padded */
                for (size_t s = 0; s * MR < il; s++) {
                    float *dst = apack + s * kl * MR;
                    size_t hgt = (il - s * MR < MR) ? il - s * MR : MR;
                    for (size_t kk = 0; kk < kl; kk++) {
                        for (size_t r = 0; r < hgt; r++)
                            dst[kk * MR + r] =
                                a[(ic + s * MR + r) * k + pc + kk];
                        for (size_t r = hgt; r < MR; r++)
                            dst[kk * MR + r] = 0.0f;
                    }
                }
                for (size_t jr = 0; jr < jl; jr += NR) {
                    size_t nr = (jl - jr < NR) ? jl - jr : NR;
                    for (size_t ir = 0; ir < il; ir += MR) {
                        size_t mr = (il - ir < MR) ? il - ir : MR;
                        microkernel(c + (ic + ir) * n + jc + jr, n,
                                    apack + (ir / MR) * kl * MR,
                                    bpack + (jr / NR) * kl * NR, kl, mr, nr,
                                    first);
                    }
                }
            }
        }
    }
    free(apack);
    free(bpack);
}

typedef void (*gemm_fn)(float *, const float *, const float *, size_t, size_t,
                        size_t);

static double time_gemm(gemm_fn f, float *c, const float *a, const float *b,
                        size_t n, int reps) {
    f(c, a, b, n, n, n); /* warm */
    double best = 1e30;
    for (int r = 0; r < reps; r++) {
        double t0 = now_secs();
        f(c, a, b, n, n, n);
        double dt = now_secs() - t0;
        if (dt < best) best = dt;
    }
    return best;
}

int main(void) {
    const size_t n = 256;
    float *a = malloc(n * n * sizeof(float));
    float *b = malloc(n * n * sizeof(float));
    float *c0 = malloc(n * n * sizeof(float));
    float *c1 = malloc(n * n * sizeof(float));
    uint64_t s = 0x243f6a8885a308d3ULL;
    for (size_t i = 0; i < n * n; i++) {
        s = s * 6364136223846793005ULL + 1442695040888963407ULL;
        a[i] = (float)((double)(s >> 33) / 4294967296.0) - 0.25f;
        s = s * 6364136223846793005ULL + 1442695040888963407ULL;
        b[i] = (float)((double)(s >> 33) / 4294967296.0) - 0.25f;
    }

    gemm_unpacked(c0, a, b, n, n, n);
    gemm_packed(c1, a, b, n, n, n);
    if (memcmp(c0, c1, n * n * sizeof(float)) != 0) {
        fprintf(stderr, "FAIL: packed and unpacked disagree bitwise\n");
        return 1;
    }

    double gf = 2.0 * (double)n * (double)n * (double)n / 1e9;
    double tu = time_gemm(gemm_unpacked, c0, a, b, n, 10);
    double tp = time_gemm(gemm_packed, c1, a, b, n, 10);
    printf("bitwise check: packed == unpacked\n");
    printf("unpacked 256^3: %.6e s  %.2f GFLOP/s\n", tu, gf / tu);
    printf("packed   256^3: %.6e s  %.2f GFLOP/s\n", tp, gf / tp);
    printf("speedup: %.2fx\n", tu / tp);
    printf("json: {\"packed_gflops\": %.6f, \"packed_secs\": %.9f, "
           "\"unpacked_gflops\": %.6f, \"unpacked_secs\": %.9f}\n",
           gf / tp, tp, gf / tu, tu);
    return 0;
}
