#!/usr/bin/env python3
"""Diff a fresh BENCH_*.json against its committed baseline.

Usage: check_bench_regression.py BASELINE FRESH [--tolerance 0.15]
           [--tolerance-mt 0.15] [--packed-speedup 1.5]

Schema (written by benches/support write_bench_json): {"bench", "bootstrap",
"rows": [{"key", "kernel", "shape", "b_p", "threads", "gflops", "mean_secs"}]}.

Checks, in order:

1. PHYSICS (always, on the fresh run): the paper's b_p effect must hold —
   for at least one conv shape, the b_p = b row beats the b_p = 1 row
   (one large lowered GEMM >= many small ones, paper Fig 4). A fresh run
   where batching stopped winning is a kernel regression no matter what
   the baseline says.
2. PACKED SPEEDUP (always, on the fresh run, when both rows exist): the
   packed-microkernel single-thread 256^3 GEMM row must be at least
   --packed-speedup times the unpacked C-tile-stationary reference row
   (gemm_256x256x256_t1_unpacked) — the packed schedule earning its keep
   is an acceptance number, not a trend.
3. THROUGHPUT DIFF (only against a non-bootstrap baseline): per row key
   present in BOTH files, normalized throughput (row gflops / calibration
   row gflops, calibration = single-thread 256^3 GEMM) must not drop more
   than --tolerance below the baseline's. Rows with threads > 1 get their
   own --tolerance-mt gate: multi-thread throughput is noisier on shared
   CI runners (core count, sibling load), so it is classed separately
   instead of loosening the single-thread gate. Normalizing by the
   calibration row makes the diff about the SHAPE of the perf profile,
   not the CI machine of the week. Rows only in one file warn (thread
   sweeps are machine-dependent) — they never fail the build.

A baseline with "bootstrap": true was seeded without trustworthy absolute
numbers (e.g. committed from a box that cannot run the Rust toolchain):
step 2 is skipped with a warning. Refresh the baseline by copying the
fresh results file over it once step 1 passes on real hardware.
"""

import argparse
import json
import sys

CALIBRATION_KEY = "gemm_256x256x256_t1"
UNPACKED_KEY = "gemm_256x256x256_t1_unpacked"


def load(path):
    with open(path) as f:
        doc = json.load(f)
    rows = {r["key"]: r for r in doc.get("rows", [])}
    if not rows:
        sys.exit(f"error: {path} has no rows")
    return doc, rows


def check_bp_effect(rows, label):
    """Paper Fig 4: b_p = b beats b_p = 1 on >= 1 conv shape."""
    by_shape = {}
    for r in rows.values():
        if r.get("kernel") == "conv" and r.get("b_p", 0) > 0:
            by_shape.setdefault(r["shape"], []).append(r)
    if not by_shape:
        print(f"warning: {label} has no conv b_p sweep; skipping b_p check")
        return True
    wins = []
    for shape, group in sorted(by_shape.items()):
        group.sort(key=lambda r: r["b_p"])
        lo, hi = group[0], group[-1]
        if lo["b_p"] == hi["b_p"]:
            continue
        ratio = hi["gflops"] / lo["gflops"] if lo["gflops"] else float("inf")
        ok = hi["gflops"] > lo["gflops"]
        wins.append(ok)
        print(
            f"  b_p effect [{shape}]: b_p={hi['b_p']} {hi['gflops']:.2f} GFLOP/s "
            f"vs b_p={lo['b_p']} {lo['gflops']:.2f} ({ratio:.2f}x) "
            f"{'OK' if ok else 'NO WIN'}"
        )
    if not any(wins):
        print(f"FAIL: {label}: b_p=b no longer beats b_p=1 on any conv shape")
        return False
    return True


def check_packed_speedup(rows, label, min_ratio):
    """Packed microkernel >= min_ratio x the unpacked reference (t=1)."""
    packed, unpacked = rows.get(CALIBRATION_KEY), rows.get(UNPACKED_KEY)
    if not packed or not unpacked:
        print(
            f"warning: {label} lacks {CALIBRATION_KEY!r} or {UNPACKED_KEY!r}; "
            "skipping packed-speedup check"
        )
        return True
    if not unpacked["gflops"]:
        print(f"FAIL: {label}: unpacked reference row has zero throughput")
        return False
    ratio = packed["gflops"] / unpacked["gflops"]
    ok = ratio >= min_ratio
    print(
        f"  packed speedup: packed {packed['gflops']:.2f} vs unpacked "
        f"{unpacked['gflops']:.2f} GFLOP/s ({ratio:.2f}x) "
        f"{'OK' if ok else f'BELOW {min_ratio:.2f}x'}"
    )
    if not ok:
        print(f"FAIL: {label}: packed GEMM no longer >= {min_ratio:.2f}x unpacked")
    return ok


def check_regressions(base_rows, fresh_rows, tolerance, tolerance_mt):
    cal_b = base_rows.get(CALIBRATION_KEY)
    cal_f = fresh_rows.get(CALIBRATION_KEY)
    if not cal_b or not cal_f:
        print(
            f"warning: calibration row {CALIBRATION_KEY!r} missing "
            "(baseline and fresh must share it); comparing raw GFLOP/s"
        )
        norm_b = norm_f = 1.0
    else:
        norm_b, norm_f = cal_b["gflops"], cal_f["gflops"]
    shared = sorted(set(base_rows) & set(fresh_rows) - {CALIBRATION_KEY})
    only_base = sorted(set(base_rows) - set(fresh_rows))
    only_fresh = sorted(set(fresh_rows) - set(base_rows))
    for k in only_base:
        print(f"warning: row {k!r} in baseline but not in fresh run (machine-dependent sweep?)")
    for k in only_fresh:
        print(f"note: new row {k!r} not in baseline yet")
    ok = True
    for k in shared:
        multi = fresh_rows[k].get("threads", 1) > 1
        row_tol = tolerance_mt if multi else tolerance
        b = base_rows[k]["gflops"] / norm_b
        f = fresh_rows[k]["gflops"] / norm_f
        drop = 1.0 - f / b if b else 0.0
        status = "ok"
        if drop > row_tol:
            status = f"REGRESSION ({drop:.0%} > {row_tol:.0%})"
            ok = False
        cls = "mt" if multi else "st"
        print(f"  {k} [{cls}]: baseline {b:.3f} fresh {f:.3f} (normalized) {status}")
    return ok


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="max allowed normalized throughput drop per row")
    ap.add_argument("--tolerance-mt", type=float, default=0.15,
                    help="separate gate for threads>1 rows (noisier on shared runners)")
    ap.add_argument("--packed-speedup", type=float, default=1.5,
                    help="min packed/unpacked single-thread GEMM ratio (0 disables)")
    args = ap.parse_args()

    base_doc, base_rows = load(args.baseline)
    _fresh_doc, fresh_rows = load(args.fresh)

    print(f"checking {args.fresh} against {args.baseline}")
    ok = check_bp_effect(fresh_rows, args.fresh)
    if args.packed_speedup > 0:
        ok = check_packed_speedup(fresh_rows, args.fresh, args.packed_speedup) and ok

    if base_doc.get("bootstrap"):
        print(
            f"baseline {args.baseline} is bootstrap (seeded off-toolchain): "
            "skipping throughput diff.\n"
            f"refresh it with: cp {args.fresh} {args.baseline}"
        )
    else:
        ok = check_regressions(
            base_rows, fresh_rows, args.tolerance, args.tolerance_mt
        ) and ok

    if not ok:
        sys.exit(1)
    print("bench check passed")


if __name__ == "__main__":
    main()
