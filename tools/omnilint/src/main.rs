//! omnilint: repo-invariant static analysis for the omnivore tree
//! (DESIGN.md §Analysis).
//!
//! Dependency-free on purpose — it must build offline, fast, and before
//! anything else in CI. It does not parse Rust; it strips comments and
//! string literals to a same-shape "code only" text and then enforces
//! textual invariants that the codebase maintains by convention:
//!
//! * `schema-guards` — every versioned-JSON surface keeps its
//!   unknown-field rejection and future-version refusal, and any file
//!   declaring a `*_VERSION` schema constant compares against it.
//! * `fenced-publish` — gradient publishes happen only inside
//!   `coordinator/param_server.rs`; everyone else must route through
//!   `publish_scaled_fenced` so the fault fences cannot be bypassed.
//! * `sim-wallclock` — the deterministic simulation domain never reads
//!   wall clocks (`Instant::now` / `SystemTime`).
//! * `nested-shard-lock` — inside `coordinator/`, no shard lock is
//!   taken while a shard or meta lock is held (the documented order is
//!   layout -> one shard -> meta).
//! * `unsafe-safety-comment` — every `unsafe` token carries a
//!   `// SAFETY:` comment within the preceding 8 lines.
//!
//! Violations can be waived in `lint.toml` at the repo root; a waiver
//! without a reason, or one that matches nothing, is itself a violation.
//! Exit status: 0 clean, 1 violations, 2 usage/IO error.

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// How many lines above an `unsafe` token a `SAFETY:` comment may sit
/// (covers a shared comment above paired `unsafe impl Send`/`Sync`).
const SAFETY_LOOKBACK: usize = 8;

#[derive(Debug)]
struct Violation {
    /// Repo-relative path with `/` separators.
    path: String,
    /// 1-based; 0 for whole-file findings.
    line: usize,
    lint: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.lint, self.msg)
    }
}

#[derive(Debug, Default)]
struct Waiver {
    lint: String,
    path: String,
    reason: String,
    /// Declaration line in lint.toml, for reporting.
    line: usize,
    used: std::cell::Cell<bool>,
}

fn main() -> ExitCode {
    let root = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        // tools/omnilint/ -> tools/ -> repo root.
        None => Path::new(env!("CARGO_MANIFEST_DIR")).join("../.."),
    };
    let src_root = root.join("rust/src");
    if !src_root.is_dir() {
        eprintln!("omnilint: {} is not a repo root (no rust/src)", root.display());
        return ExitCode::from(2);
    }

    let (waivers, mut violations) = match load_waivers(&root.join("lint.toml")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("omnilint: bad lint.toml: {e}");
            return ExitCode::from(2);
        }
    };

    let files = match walk_rs(&src_root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("omnilint: walking {}: {e}", src_root.display());
            return ExitCode::from(2);
        }
    };
    let mut sources = Vec::new();
    for path in files {
        let raw = match std::fs::read_to_string(&path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("omnilint: reading {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let rel = rel_path(&root, &path);
        let code = strip_noncode(&raw);
        sources.push(SourceFile { rel, raw, code });
    }

    violations.extend(lint_schema_guards(&sources));
    violations.extend(lint_fenced_publish(&sources));
    violations.extend(lint_sim_wallclock(&sources));
    violations.extend(lint_nested_shard_lock(&sources));
    violations.extend(lint_unsafe_safety(&sources));

    // Waive, then flag unused waivers (a waiver that matches nothing is
    // stale documentation and must be deleted, not accumulated).
    violations.retain(|v| {
        !waivers.iter().any(|w| {
            let hit = w.lint == v.lint && v.path.ends_with(&w.path);
            if hit {
                w.used.set(true);
            }
            hit
        })
    });
    for w in &waivers {
        if !w.used.get() {
            violations.push(Violation {
                path: "lint.toml".into(),
                line: w.line,
                lint: "unused-waiver",
                msg: format!("waiver ({} on {}) matches no violation", w.lint, w.path),
            });
        }
    }

    if violations.is_empty() {
        println!("omnilint: clean ({} files, {} waivers)", sources.len(), waivers.len());
        ExitCode::SUCCESS
    } else {
        violations.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
        for v in &violations {
            println!("{v}");
        }
        println!("omnilint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

struct SourceFile {
    rel: String,
    raw: String,
    /// Same line structure as `raw`, with comment and string-literal
    /// contents blanked to spaces.
    code: String,
}

fn rel_path(root: &Path, path: &Path) -> String {
    let p = path.strip_prefix(root).unwrap_or(path);
    p.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

fn walk_rs(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in std::fs::read_dir(&d)? {
            let p = entry?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Replace comment bodies and string/char-literal contents with spaces,
/// preserving byte-for-byte line structure so line numbers in findings
/// match the original file. Handles `//`, nested `/* */`, `"…"` with
/// escapes, raw strings `r#"…"#`, char literals (including `b'…'`), and
/// the char-vs-lifetime ambiguity of `'`.
fn strip_noncode(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let blank = |out: &mut Vec<u8>, c: u8| out.push(if c == b'\n' { b'\n' } else { b' ' });
    let mut i = 0;
    while i < b.len() {
        if b[i..].starts_with(b"//") {
            while i < b.len() && b[i] != b'\n' {
                blank(&mut out, b[i]);
                i += 1;
            }
        } else if b[i..].starts_with(b"/*") {
            let mut depth = 0usize;
            while i < b.len() {
                if b[i..].starts_with(b"/*") {
                    depth += 1;
                    blank(&mut out, b' ');
                    blank(&mut out, b' ');
                    i += 2;
                } else if b[i..].starts_with(b"*/") {
                    depth -= 1;
                    blank(&mut out, b' ');
                    blank(&mut out, b' ');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
        } else if b[i] == b'r' && matches!(b.get(i + 1), Some(b'"' | b'#')) {
            // Raw string r"…" / r#"…"# / r##"…"## (also reached for
            // br"…" via the b branch below falling through per byte).
            let start = i;
            i += 1;
            let mut hashes = 0;
            while b.get(i) == Some(&b'#') {
                hashes += 1;
                i += 1;
            }
            if b.get(i) == Some(&b'"') {
                i += 1;
                let closer = format!("\"{}", "#".repeat(hashes)).into_bytes();
                while i < b.len() && !b[i..].starts_with(&closer) {
                    i += 1;
                }
                i = (i + closer.len()).min(b.len());
                for &c in &b[start..i] {
                    blank(&mut out, c);
                }
            } else {
                // `r#ident` raw identifier, not a string: emit as code.
                out.extend_from_slice(&b[start..i]);
            }
        } else if b[i] == b'"' {
            blank(&mut out, b'"');
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' && i + 1 < b.len() {
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else if b[i] == b'"' {
                    blank(&mut out, b'"');
                    i += 1;
                    break;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
        } else if b[i] == b'\'' {
            // Char literal iff it escapes or closes within two bytes;
            // otherwise it is a lifetime and stays code.
            let is_char = b.get(i + 1) == Some(&b'\\') || b.get(i + 2) == Some(&b'\'');
            if is_char {
                blank(&mut out, b'\'');
                i += 1;
                while i < b.len() {
                    if b[i] == b'\\' && i + 1 < b.len() {
                        blank(&mut out, b[i]);
                        blank(&mut out, b[i + 1]);
                        i += 2;
                    } else if b[i] == b'\'' {
                        blank(&mut out, b'\'');
                        i += 1;
                        break;
                    } else {
                        blank(&mut out, b[i]);
                        i += 1;
                    }
                }
            } else {
                out.push(b'\'');
                i += 1;
            }
        } else {
            out.push(b[i]);
            i += 1;
        }
    }
    String::from_utf8(out).expect("blanking only replaces bytes with ASCII")
}

/// Does `code` contain `word` with non-identifier bytes on both sides?
fn has_word(code: &str, word: &str) -> bool {
    let ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(off) = code[from..].find(word) {
        let at = from + off;
        let pre = at.checked_sub(1).map(|j| b[j]);
        let post = b.get(at + word.len()).copied();
        if !pre.is_some_and(ident) && !post.is_some_and(ident) {
            return true;
        }
        from = at + 1;
    }
    false
}

// ---------------------------------------------------------------------------
// Lint 1: schema-guards
// ---------------------------------------------------------------------------

/// Required markers per versioned-JSON surface. Raw-source substrings:
/// deliberately blunt, so renaming or deleting a guard breaks the build
/// here instead of silently widening the parse surface.
const SCHEMA_MARKERS: &[(&str, &[&str])] = &[
    (
        "rust/src/api/spec.rs",
        &[
            "reject_unknown(",
            "> SPEC_VERSION",
            "CLUSTER_FIELDS",
            "PROFILE_FIELDS",
            "DRIFT_STEP_FIELDS",
            "DRIFT_RAMP_FIELDS",
        ],
    ),
    ("rust/src/api/outcome.rs", &["unknown field", "> OUTCOME_VERSION"]),
    ("rust/src/config/fault.rs", &["unknown field", "> FAULT_VERSION"]),
    ("rust/src/model/checkpoint.rs", &["MAX_RANK", "MAX_DIM", "MAX_TENSORS"]),
];

fn lint_schema_guards(sources: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for (path, markers) in SCHEMA_MARKERS {
        let Some(f) = sources.iter().find(|f| f.rel == *path) else {
            out.push(Violation {
                path: (*path).into(),
                line: 0,
                lint: "schema-guards",
                msg: "versioned-JSON surface file is missing".into(),
            });
            continue;
        };
        for m in *markers {
            if !f.raw.contains(m) {
                out.push(Violation {
                    path: f.rel.clone(),
                    line: 0,
                    lint: "schema-guards",
                    msg: format!("required schema guard {m:?} not found"),
                });
            }
        }
    }
    // Generic rule: declaring a schema-version constant obliges the file
    // to refuse future versions by comparing against it.
    for f in sources {
        for (i, line) in f.code.lines().enumerate() {
            let Some(at) = line.find("const ") else { continue };
            let rest = &line[at + "const ".len()..];
            let ident: String =
                rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
            if ident.ends_with("_VERSION") && !f.code.contains(&format!("> {ident}")) {
                out.push(Violation {
                    path: f.rel.clone(),
                    line: i + 1,
                    lint: "schema-guards",
                    msg: format!(
                        "declares {ident} but never rejects versions above it (`> {ident}`)"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Lint 2: fenced-publish
// ---------------------------------------------------------------------------

/// The only file allowed to call `.publish(` / `.publish_scaled(`: the
/// server's own impl and unit tests. (`.publish_scaled_fenced(` matches
/// neither pattern — the `_f` breaks both.)
const PUBLISH_HOME: &str = "rust/src/coordinator/param_server.rs";

fn lint_fenced_publish(sources: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in sources {
        if f.rel == PUBLISH_HOME {
            continue;
        }
        for (i, line) in f.code.lines().enumerate() {
            if line.contains(".publish(") || line.contains(".publish_scaled(") {
                out.push(Violation {
                    path: f.rel.clone(),
                    line: i + 1,
                    lint: "fenced-publish",
                    msg: "unfenced gradient publish; route through publish_scaled_fenced"
                        .into(),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Lint 3: sim-wallclock
// ---------------------------------------------------------------------------

/// The deterministic simulation domain: identical inputs must give
/// identical traces, so wall clocks are banned.
const SIM_DOMAIN: &[&str] =
    &["rust/src/sim/", "rust/src/engine/sim_time.rs", "rust/src/data/plan_controller.rs"];

/// Real-time domains where wall clocks are the point, not a leak: the
/// serve daemon (token-bucket refill, IO timeouts), the real-thread
/// scheduler, and the bench harness. Scoped here — NOT via lint.toml
/// waivers — because the boundary is architectural, not an exception:
/// these paths must never be folded into [`SIM_DOMAIN`] (the tests
/// assert the two lists stay disjoint).
const WALLCLOCK_OK: &[&str] =
    &["rust/src/serve/", "rust/src/engine/threaded.rs", "rust/src/util/bench.rs"];

fn lint_sim_wallclock(sources: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in sources {
        if WALLCLOCK_OK.iter().any(|d| f.rel.starts_with(d)) {
            continue;
        }
        if !SIM_DOMAIN.iter().any(|d| f.rel.starts_with(d)) {
            continue;
        }
        for (i, line) in f.code.lines().enumerate() {
            for pat in ["Instant::now", "SystemTime"] {
                if line.contains(pat) {
                    out.push(Violation {
                        path: f.rel.clone(),
                        line: i + 1,
                        lint: "sim-wallclock",
                        msg: format!("{pat} inside the deterministic sim domain"),
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Lint 4: nested-shard-lock
// ---------------------------------------------------------------------------

/// Conservative brace-scoped model of guard lifetimes in `coordinator/`:
/// a guard acquired at brace depth d is considered held until the block
/// at depth d closes. Acquiring a shard lock (`.data.lock(`) while a
/// shard or meta guard is live, or a meta lock (`.meta.lock(`) while a
/// meta guard is live, is the deadlock/inversion shape the runtime
/// `lock_order` tokens catch dynamically — this catches it at lint time.
fn lint_nested_shard_lock(sources: &[SourceFile]) -> Vec<Violation> {
    #[derive(Clone, Copy, PartialEq)]
    enum Kind {
        Shard,
        Meta,
    }
    let mut out = Vec::new();
    for f in sources {
        if !f.rel.starts_with("rust/src/coordinator/") {
            continue;
        }
        let mut depth = 0usize;
        let mut held: Vec<(Kind, usize)> = Vec::new();
        for (ln, line) in f.code.lines().enumerate() {
            let b = line.as_bytes();
            for (col, &c) in b.iter().enumerate() {
                match c {
                    b'{' => depth += 1,
                    b'}' => {
                        depth = depth.saturating_sub(1);
                        held.retain(|&(_, d)| d <= depth);
                    }
                    b'.' => {
                        let kind = if line[col..].starts_with(".data.lock(") {
                            Some(Kind::Shard)
                        } else if line[col..].starts_with(".meta.lock(") {
                            Some(Kind::Meta)
                        } else {
                            None
                        };
                        let Some(kind) = kind else { continue };
                        let conflict = held.iter().any(|&(h, _)| match kind {
                            // Second shard, or shard after meta: both
                            // break the layout -> shard -> meta order.
                            Kind::Shard => true,
                            Kind::Meta => h == Kind::Meta,
                        });
                        if conflict {
                            out.push(Violation {
                                path: f.rel.clone(),
                                line: ln + 1,
                                lint: "nested-shard-lock",
                                msg: "lock acquired while a shard/meta guard may be held \
                                      (order is layout -> one shard -> meta)"
                                    .into(),
                            });
                        }
                        held.push((kind, depth));
                    }
                    _ => {}
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Lint 5: unsafe-safety-comment
// ---------------------------------------------------------------------------

fn lint_unsafe_safety(sources: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for f in sources {
        let raw_lines: Vec<&str> = f.raw.lines().collect();
        for (i, line) in f.code.lines().enumerate() {
            if !has_word(line, "unsafe") {
                continue;
            }
            let from = i.saturating_sub(SAFETY_LOOKBACK);
            let documented = raw_lines[from..=i.min(raw_lines.len() - 1)]
                .iter()
                .any(|l| l.contains("SAFETY:"));
            if !documented {
                out.push(Violation {
                    path: f.rel.clone(),
                    line: i + 1,
                    lint: "unsafe-safety-comment",
                    msg: format!(
                        "`unsafe` without a // SAFETY: comment within {SAFETY_LOOKBACK} lines"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Waivers
// ---------------------------------------------------------------------------

/// Parse the `[[waiver]]` entries of lint.toml (a deliberately tiny TOML
/// subset: table arrays of `key = "value"` lines, `#` comments). Returns
/// the waivers plus violations for malformed entries (a waiver with no
/// reason documents nothing).
fn load_waivers(path: &Path) -> Result<(Vec<Waiver>, Vec<Violation>), String> {
    let mut waivers: Vec<Waiver> = Vec::new();
    let mut violations = Vec::new();
    let Ok(text) = std::fs::read_to_string(path) else {
        return Ok((waivers, violations)); // no lint.toml: no waivers
    };
    for (i, raw_line) in text.lines().enumerate() {
        let line = match raw_line.find('#') {
            Some(h) => &raw_line[..h],
            None => raw_line,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if line == "[[waiver]]" {
            waivers.push(Waiver { line: i + 1, ..Waiver::default() });
            continue;
        }
        let Some((key, val)) = line.split_once('=') else {
            return Err(format!("line {}: expected [[waiver]] or key = \"value\"", i + 1));
        };
        let val = val.trim();
        let Some(val) = val.strip_prefix('"').and_then(|v| v.strip_suffix('"')) else {
            return Err(format!("line {}: value must be double-quoted", i + 1));
        };
        let val = val.to_string();
        let Some(w) = waivers.last_mut() else {
            return Err(format!("line {}: key outside a [[waiver]] block", i + 1));
        };
        match key.trim() {
            "lint" => w.lint = val,
            "path" => w.path = val,
            "reason" => w.reason = val,
            other => return Err(format!("line {}: unknown key {other:?}", i + 1)),
        }
    }
    for w in &waivers {
        if w.lint.is_empty() || w.path.is_empty() || w.reason.trim().is_empty() {
            violations.push(Violation {
                path: "lint.toml".into(),
                line: w.line,
                lint: "undocumented-waiver",
                msg: "waiver needs non-empty lint, path, and reason".into(),
            });
        }
    }
    Ok((waivers, violations))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripper_blanks_comments_and_strings() {
        let src = "let x = \"unsafe\"; // unsafe here\nlet y = 'u'; /* unsafe */ z";
        let code = strip_noncode(src);
        assert!(!code.contains("unsafe"));
        assert!(code.contains("let x ="));
        assert!(code.contains('z'));
        assert_eq!(src.lines().count(), code.lines().count());
    }

    #[test]
    fn stripper_handles_raw_strings_lifetimes_and_bytes() {
        let code = strip_noncode("r#\"unsafe \" quote\"# fn f<'a>(x: &'a u8) { b'\\n'; }");
        assert!(!code.contains("unsafe"));
        assert!(code.contains("fn f<'a>(x: &'a u8)"));
        let code = strip_noncode("match c { b' ' | b'\\t' => unsafe_site() }");
        assert!(code.contains("unsafe_site"), "{code}");
        assert!(!code.contains("b' '"));
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(has_word("x=unsafe{", "unsafe"));
        assert!(!has_word("unsafely", "unsafe"));
        assert!(!has_word("not_unsafe", "unsafe"));
    }

    fn file(rel: &str, raw: &str) -> SourceFile {
        SourceFile { rel: rel.into(), raw: raw.into(), code: strip_noncode(raw) }
    }

    #[test]
    fn version_const_needs_guard() {
        let f = file("rust/src/x.rs", "pub const FOO_VERSION: u64 = 1;\n");
        let v = lint_schema_guards(std::slice::from_ref(&f));
        assert!(v.iter().any(|v| v.msg.contains("FOO_VERSION")), "{v:?}");
        let ok = file(
            "rust/src/x.rs",
            "pub const FOO_VERSION: u64 = 1;\nif version > FOO_VERSION { }\n",
        );
        let v = lint_schema_guards(std::slice::from_ref(&ok));
        assert!(!v.iter().any(|v| v.msg.contains("FOO_VERSION")), "{v:?}");
    }

    #[test]
    fn publish_outside_home_flagged() {
        let bad = file("rust/src/engine/driver.rs", "ps.publish_scaled(&g, v, 1.0);\n");
        assert_eq!(lint_fenced_publish(std::slice::from_ref(&bad)).len(), 1);
        let fenced =
            file("rust/src/engine/driver.rs", "ps.publish_scaled_fenced(&g, v, 1.0, 0, 0);\n");
        assert!(lint_fenced_publish(std::slice::from_ref(&fenced)).is_empty());
        let home = file("rust/src/coordinator/param_server.rs", "self.publish(&g, v);\n");
        assert!(lint_fenced_publish(std::slice::from_ref(&home)).is_empty());
    }

    #[test]
    fn wallclock_in_sim_domain_flagged() {
        let bad = file("rust/src/sim/timing.rs", "let t = Instant::now();\n");
        assert_eq!(lint_sim_wallclock(std::slice::from_ref(&bad)).len(), 1);
        let ok = file("rust/src/engine/threaded.rs", "let t = Instant::now();\n");
        assert!(lint_sim_wallclock(std::slice::from_ref(&ok)).is_empty());
    }

    #[test]
    fn wallclock_domains_are_disjoint_and_serve_is_real_time() {
        // The serve daemon reads real clocks by design (rate limiting,
        // IO timeouts) — no violation, and no lint.toml waiver needed.
        let serve = file(
            "rust/src/serve/limits.rs",
            "let now = Instant::now();\nlet t = SystemTime::now();\n",
        );
        assert!(lint_sim_wallclock(std::slice::from_ref(&serve)).is_empty());
        // The carve-out is a boundary, not an override: nothing in the
        // sim domain may ever also match WALLCLOCK_OK.
        for sim in SIM_DOMAIN {
            for ok in WALLCLOCK_OK {
                assert!(
                    !sim.starts_with(ok) && !ok.starts_with(sim),
                    "{sim} and {ok} overlap; sim determinism would silently unravel"
                );
            }
        }
    }

    #[test]
    fn nested_locks_flagged_by_scope() {
        let bad = file(
            "rust/src/coordinator/x.rs",
            "fn f(&self) {\n  let a = self.meta.lock();\n  let b = other.meta.lock();\n}\n",
        );
        assert_eq!(lint_nested_shard_lock(std::slice::from_ref(&bad)).len(), 1);
        // Sequential inner scopes release before re-acquiring.
        let ok = file(
            "rust/src/coordinator/x.rs",
            "fn f(&self) {\n  { let a = self.meta.lock(); }\n  let b = self.meta.lock();\n}\n",
        );
        assert!(lint_nested_shard_lock(std::slice::from_ref(&ok)).is_empty());
        // Meta under shard breaks the documented order.
        let inv = file(
            "rust/src/coordinator/x.rs",
            "fn f(&self) {\n  let a = s.meta.lock();\n  let b = s.data.lock();\n}\n",
        );
        assert_eq!(lint_nested_shard_lock(std::slice::from_ref(&inv)).len(), 1);
    }

    #[test]
    fn undocumented_unsafe_flagged() {
        let bad = file("rust/src/x.rs", "fn f() {\n  unsafe { g() }\n}\n");
        assert_eq!(lint_unsafe_safety(std::slice::from_ref(&bad)).len(), 1);
        let ok = file("rust/src/x.rs", "// SAFETY: g has no preconditions\nunsafe { g() }\n");
        assert!(lint_unsafe_safety(std::slice::from_ref(&ok)).is_empty());
        // The word inside a comment or string is not an unsafe token.
        let doc = file("rust/src/x.rs", "// mentions unsafe\nlet s = \"unsafe\";\n");
        assert!(lint_unsafe_safety(std::slice::from_ref(&doc)).is_empty());
    }

    #[test]
    fn waiver_parsing_and_validation() {
        let dir = std::env::temp_dir().join("omnilint_waiver_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("lint.toml");
        std::fs::write(
            &p,
            "# header\n[[waiver]]\nlint = \"sim-wallclock\"\npath = \"rust/src/sim/x.rs\"\n\
             reason = \"calibration shim\"\n[[waiver]]\nlint = \"fenced-publish\"\n\
             path = \"rust/src/y.rs\"\nreason = \"\"\n",
        )
        .unwrap();
        let (ws, vs) = load_waivers(&p).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(vs.len(), 1, "empty reason is a violation: {vs:?}");
        assert!(load_waivers(&dir.join("absent.toml")).unwrap().0.is_empty());
        assert!(load_waivers(&{
            std::fs::write(&p, "lint = \"x\"\n").unwrap();
            p.clone()
        })
        .is_err());
    }
}
